//! Executable loading + invocation over the PJRT CPU client.
//!
//! HLO *text* artifacts (see python/compile/aot.py for why text) are parsed
//! into `HloModuleProto`s, compiled once, and cached in a registry keyed by
//! executable name. Invocations take a mix of device-resident buffers
//! (weights, KV caches) and fresh host tensors (tokens, lengths); outputs
//! come back as device buffers so state can be threaded into the next call
//! without host round-trips.

use std::cell::Cell;
use std::collections::HashMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::tensors::{literal_to_host, HostData, HostTensor};

pub struct Runtime {
    pub client: xla::PjRtClient,
    execs: HashMap<String, LoadedExec>,
    pub compile_time: Duration,
    pub exec_calls: u64,
    pub exec_time: Duration,
    /// time spent splitting tuple results via the host (perf-pass target)
    pub untuple_time: Duration,
    /// Host-transfer accounting at the runtime boundary: every `upload`
    /// (including the per-call `Arg::Host` uploads) and every `download`
    /// bumps a counter + byte total. `Cell` because upload/download take
    /// `&self`. The engine snapshots these around `step()` to attribute
    /// transfers per decode step (EngineMetrics) — the zero-download
    /// steady-state AC of the device-resident decode path is measured here,
    /// not asserted. Internal untuple round-trips are deliberately NOT
    /// counted: they are an xla-crate artifact, not engine-driven traffic.
    pub uploads: Cell<u64>,
    pub upload_bytes: Cell<u64>,
    pub downloads: Cell<u64>,
    pub download_bytes: Cell<u64>,
}

pub struct LoadedExec {
    pub name: String,
    pub exe: xla::PjRtLoadedExecutable,
}

/// An argument to an executable invocation.
pub enum Arg<'a> {
    /// Device-resident buffer (weights, threaded KV state).
    Buf(&'a xla::PjRtBuffer),
    /// Host tensor uploaded for this call (tokens, lengths).
    Host(&'a HostTensor),
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(Runtime {
            client,
            execs: HashMap::new(),
            compile_time: Duration::ZERO,
            exec_calls: 0,
            exec_time: Duration::ZERO,
            untuple_time: Duration::ZERO,
            uploads: Cell::new(0),
            upload_bytes: Cell::new(0),
            downloads: Cell::new(0),
            download_bytes: Cell::new(0),
        })
    }

    /// Snapshot of the transfer counters: (uploads, upload_bytes, downloads,
    /// download_bytes). Diff two snapshots to attribute traffic to a region.
    pub fn transfer_snapshot(&self) -> (u64, u64, u64, u64) {
        (
            self.uploads.get(),
            self.upload_bytes.get(),
            self.downloads.get(),
            self.download_bytes.get(),
        )
    }

    /// Load + compile an HLO text file under `name` (idempotent).
    pub fn load(&mut self, name: &str, path: &Path) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {name}"))?;
        self.compile_time += t0.elapsed();
        self.execs.insert(name.to_string(), LoadedExec { name: name.to_string(), exe });
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.execs.contains_key(name)
    }

    pub fn loaded_count(&self) -> usize {
        self.execs.len()
    }

    /// Upload a host tensor as a device-resident buffer.
    ///
    /// Goes through a Literal + the patched `buffer_from_host_literal`
    /// (which awaits the transfer): the stock `buffer_from_host_buffer`
    /// path may alias the host allocation past the call under TFRT-CPU's
    /// buffer semantics, corrupting weights once the source Vec is freed.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        self.uploads.set(self.uploads.get() + 1);
        self.upload_bytes.set(self.upload_bytes.get() + 4 * t.numel() as u64);
        let lit = match &t.data {
            HostData::F32(v) => {
                let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    &t.dims,
                    &bytes,
                )?
            }
            HostData::I32(v) => {
                let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    &t.dims,
                    &bytes,
                )?
            }
        };
        Ok(self.client.buffer_from_host_literal(None, &lit)?)
    }

    /// Invoke an executable; returns one device buffer per result.
    pub fn call(&mut self, name: &str, args: &[Arg]) -> Result<Vec<xla::PjRtBuffer>> {
        // upload host args, then execute over buffers
        enum Slot<'a> {
            Ext(&'a xla::PjRtBuffer),
            Own(usize),
        }
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut slots: Vec<Slot> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::Buf(b) => slots.push(Slot::Ext(b)),
                Arg::Host(t) => {
                    owned.push(self.upload(t)?);
                    slots.push(Slot::Own(owned.len() - 1));
                }
            }
        }
        let refs: Vec<&xla::PjRtBuffer> = slots
            .iter()
            .map(|s| match s {
                Slot::Ext(b) => *b,
                Slot::Own(i) => &owned[*i],
            })
            .collect();
        let exec = self.execs.get(name).ok_or_else(|| anyhow!("executable {name} not loaded"))?;
        let t0 = Instant::now();
        let mut out = exec.exe.execute_b(&refs)?;
        self.exec_time += t0.elapsed();
        self.exec_calls += 1;
        if out.len() != 1 {
            anyhow::bail!("{name}: expected 1 replica, got {}", out.len());
        }
        let bufs = out.remove(0);
        self.untuple(bufs)
    }

    /// The vendored xla crate executes with `untuple_result = false`, so a
    /// multi-result HLO comes back as ONE tuple-shaped buffer. Split it into
    /// per-leaf device buffers (host round-trip; the perf pass replaces this
    /// with a patched `execute_b` that untuples on-device — see
    /// EXPERIMENTS.md §Perf).
    fn untuple(&mut self, bufs: Vec<xla::PjRtBuffer>) -> Result<Vec<xla::PjRtBuffer>> {
        if bufs.len() != 1 {
            return Ok(bufs);
        }
        let shape = bufs[0].on_device_shape()?;
        if !shape.is_tuple() {
            return Ok(bufs);
        }
        let t0 = Instant::now();
        let lit = bufs[0].to_literal_sync()?;
        let leaves = lit.to_tuple()?;
        let mut out = Vec::with_capacity(leaves.len());
        for leaf in &leaves {
            out.push(self.client.buffer_from_host_literal(None, leaf)?);
        }
        self.untuple_time += t0.elapsed();
        Ok(out)
    }

    /// Download a device buffer to the host.
    pub fn download(&self, buf: &xla::PjRtBuffer) -> Result<HostTensor> {
        let lit = buf.to_literal_sync()?;
        let t = literal_to_host(&lit)?;
        self.downloads.set(self.downloads.get() + 1);
        self.download_bytes
            .set(self.download_bytes.get() + 4 * t.numel() as u64);
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    // Runtime integration tests live in rust/tests/integration_runtime.rs —
    // they need artifacts/ built. Unit-level coverage here is limited to the
    // argument plumbing types.
    use super::*;

    #[test]
    fn host_tensor_arg_shapes() {
        let t = HostTensor::i32(&[2, 2], vec![1, 2, 3, 4]);
        match Arg::Host(&t) {
            Arg::Host(h) => assert_eq!(h.numel(), 4),
            _ => unreachable!(),
        }
    }
}
