"""Training framework: row preparation, loss descent, variant plumbing."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import (
    MASK_ID, TARGETS, DrafterConfig, TrainConfig, all_drafters,
    drafter_train_config, get_drafter,
)
from compile.masks import PrecomputedMask
from compile.model import init_target, target_features
from compile.optim import adam_init, adam_update, linear_schedule
from compile.train import max_rows, prepare_ar_example, prepare_example, train_drafter


@pytest.fixture(scope="module")
def teacher():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_prepare_example_contract(teacher):
    cfg, tp = teacher
    n = 48
    rng = np.random.default_rng(0)
    tokens = rng.integers(4, 250, size=n).astype(np.int32)
    feats = np.asarray(target_features(tp, cfg, jnp.asarray(tokens[None]))[0][0])
    tc = TrainConfig(seq_len=n, k_train=6)
    src = PrecomputedMask(n, 6)
    rp = max_rows(tc.__class__(seq_len=n, k_train=6))
    batches = prepare_example(tokens, feats, tc, src, rng, rp=rp)
    assert len(batches) == 1
    b = batches[0]
    valid = b["valid"][0]
    d = b["depth"][0][valid]
    p = b["pos"][0][valid]
    tok = b["tok_in"][0][valid]
    lab = b["label"][0][valid]
    # depth-0 rows carry real tokens; MTP rows carry MASK
    assert (tok[d == 0] == tokens[p[d == 0] + 1]).all()
    assert (tok[d > 0] == MASK_ID).all()
    assert (lab == tokens[p + 2]).all()
    # mask diag (self-attention) set for valid rows
    m = b["mask"][0]
    idx = np.where(valid)[0]
    assert m[idx, idx].all()


def test_prepare_example_segments_partition_losses(teacher):
    cfg, tp = teacher
    n = 64
    rng = np.random.default_rng(1)
    tokens = rng.integers(4, 250, size=n).astype(np.int32)
    feats = np.asarray(target_features(tp, cfg, jnp.asarray(tokens[None]))[0][0])
    tc = TrainConfig(seq_len=n, segments=3)
    src = PrecomputedMask(n, tc.k_train)
    rng2 = np.random.default_rng(1)
    full = prepare_example(tokens, feats, TrainConfig(seq_len=n), src,
                           np.random.default_rng(1))
    segs = prepare_example(tokens, feats, tc, src, rng2)
    n_loss_full = sum(b["loss_w"].sum() for b in full)
    n_loss_segs = sum(b["loss_w"].sum() for b in segs)
    assert n_loss_full == n_loss_segs  # every row's loss owned exactly once


def test_prepare_ar_example(teacher):
    cfg, tp = teacher
    rng = np.random.default_rng(2)
    tokens = rng.integers(4, 250, size=32).astype(np.int32)
    feats = np.asarray(target_features(tp, cfg, jnp.asarray(tokens[None]))[0][0])
    b = prepare_ar_example(tokens, feats)[0]
    valid = b["valid"][0]
    assert valid.sum() == 30  # m = n - 2
    assert (b["depth"][0][valid] == 0).all()
    m = b["mask"][0][:30, :30]
    assert (m == np.tril(np.ones((30, 30), bool))).all()


def test_max_rows_bounds_actual(teacher):
    for seq_len, segments in [(32, 1), (48, 2), (96, 1), (96, 4)]:
        tc = TrainConfig(seq_len=seq_len, segments=segments)
        rp = max_rows(tc)
        cfg, tp = teacher
        rng = np.random.default_rng(seq_len)
        tokens = rng.integers(4, 250, size=seq_len).astype(np.int32)
        feats = np.zeros((seq_len, cfg.feature_dim), np.float32)
        src = PrecomputedMask(seq_len, tc.k_train)
        for b in prepare_example(tokens, feats, tc, src, rng, rp=rp):
            assert b["valid"].shape[1] == rp


def test_short_training_reduces_loss(teacher):
    cfg, tp = teacher
    dcfg = DrafterConfig(name="smoke", target="target-m", n_layers=1)
    tc = TrainConfig(seq_len=48, steps=14, batch=2, lr=3e-3)
    _, log, _ = train_drafter(tp, cfg, dcfg, tc, verbose=False)
    assert log["loss"][-1] < log["loss"][0]


def test_frozen_embeddings_stay_frozen(teacher):
    cfg, tp = teacher
    dcfg = DrafterConfig(name="fz", target="target-m", n_layers=1,
                         freeze_embeddings=True)
    tc = TrainConfig(seq_len=32, steps=4, batch=1)
    params, _, _ = train_drafter(tp, cfg, dcfg, tc, verbose=False)
    np.testing.assert_array_equal(
        np.asarray(params["embed"]), np.asarray(tp["embed"][:, :dcfg.d_model]))


def test_reg_variant_logs_alpha(teacher):
    cfg, tp = teacher
    dcfg = DrafterConfig(name="rg", target="target-m", n_layers=1,
                         hidden_mode="reg_ntp")
    tc = TrainConfig(seq_len=32, steps=4, batch=1)
    params, log, _ = train_drafter(tp, cfg, dcfg, tc, verbose=False)
    assert "alpha" in params and len(log["alpha"]) > 0


def test_snapshots_taken(teacher):
    cfg, tp = teacher
    dcfg = DrafterConfig(name="sn", target="target-m", n_layers=1)
    tc = TrainConfig(seq_len=32, steps=6, batch=1)
    _, _, snaps = train_drafter(tp, cfg, dcfg, tc, snapshot_steps=(2, 4),
                                verbose=False)
    assert set(snaps) == {2, 4}


def test_adam_and_schedule():
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.ones((4,))}
    st_ = adam_init(p)
    p2, st2 = adam_update(p, g, st_, 0.1)
    assert (np.asarray(p2["w"]) < 1.0).all()
    assert float(linear_schedule(0, 100, 1.0, 10)) == 0.0
    assert abs(float(linear_schedule(10, 100, 1.0, 10)) - 1.0) < 1e-6
    assert float(linear_schedule(100, 100, 1.0, 10)) == 0.0


def test_variant_registry_complete():
    names = {d.name for d in all_drafters()}
    # every experiment's variants exist
    for want in ["target-m-pe4", "target-m-pe2", "target-m-pe1", "target-m-ar",
                 "target-m-hs-depth", "target-m-hs-reg", "target-m-frozen",
                 "target-m-ktr5", "target-m-seq48", "target-l-pe-n512",
                 "target-l-ps-n64", "target-l-pard-n64", "target-s-pe4"]:
        assert want in names, want
    # train-config plumbing
    assert drafter_train_config(get_drafter("target-m-ktr5")).k_train == 5
    assert drafter_train_config(get_drafter("target-m-seq48")).seq_len == 48
    assert drafter_train_config(get_drafter("target-l-pard-n64")).mask_mode == "pard"
    assert drafter_train_config(get_drafter("target-l-pe-n512")).segments == 4
