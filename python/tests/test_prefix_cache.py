"""Prefix-cache tail prefill: bitwise parity against the full prefill.

The load-bearing property: `prefill_cached` run over only the un-cached tail
of a prompt — with the prefix KV rows seeded from an earlier prefill — must
produce BITWISE-equal last_logits, per-position feats, and KV rows to a full
`prefill` of the whole prompt. Masked attention keys contribute exactly-zero
weight and every softmax row reduces over the same S_MAX-length cache axis in
the same order, so there is no tolerance to tune: equality is exact.

Two layers of the argument are pinned separately:

  1. *Prefix reuse is sound across requests*: two prompts sharing their first
     `n` tokens produce bitwise-identical KV rows at positions [0, n) (KV row
     q depends only on tokens <= q). This is what licenses the Rust engine's
     content-addressed block sharing.
  2. *Tail-only compute is invisible*: seeding those rows and running
     `prefill_cached` over the remainder matches the full prefill exactly.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import PREFIX_TAIL_PAD, PROMPT_PAD, TARGETS
from compile.model import init_target, prefill, prefill_cached, zero_kv


@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def full_prefill(cfg, params, prompt_tokens):
    """Reference full prefill of a single prompt, PROMPT_PAD-padded."""
    plen = len(prompt_tokens)
    prompt = np.zeros((1, PROMPT_PAD), np.int32)
    prompt[0, :plen] = prompt_tokens
    return prefill(params, cfg, jnp.asarray(prompt),
                   jnp.asarray([plen], jnp.int32), zero_kv(cfg, 1))


def cached_prefill(cfg, params, prompt_tokens, start, kv_seed):
    """Tail-only prefill of prompt positions [start, plen), PAD slots filled
    with sentinel garbage (251) to prove masking — never a real token."""
    plen = len(prompt_tokens)
    tail = np.full((1, PREFIX_TAIL_PAD), 251, np.int32)
    tail[0, :plen - start] = prompt_tokens[start:]
    return prefill_cached(params, cfg, jnp.asarray(tail),
                          jnp.asarray([plen], jnp.int32),
                          jnp.asarray([start], jnp.int32), kv_seed)


def seeded_kv(kv_ref, start):
    """The engine's cache-hit seed: prefix rows [0, start) gathered from the
    shared pool, everything at or past `start` zeroed."""
    return kv_ref.at[:, :, :, start:].set(0.0)


# ---------------------------------------------------------------------------
# shared-prefix KV rows are bitwise identical across requests
# ---------------------------------------------------------------------------

def test_shared_prefix_kv_rows_are_bitwise_identical(tm):
    cfg, p = tm
    rng = np.random.default_rng(0)
    shared = np.asarray(toks(rng, (9,)))
    a = np.concatenate([shared, np.asarray(toks(rng, (5,)))])
    b = np.concatenate([shared, np.asarray(toks(rng, (3,)))])
    _, _, kv_a = full_prefill(cfg, p, a)
    _, _, kv_b = full_prefill(cfg, p, b)
    np.testing.assert_array_equal(np.asarray(kv_a)[:, :, :, :9],
                                  np.asarray(kv_b)[:, :, :, :9])
    # and the first divergent row differs — the prefix length really is 9
    assert not np.array_equal(np.asarray(kv_a)[:, :, :, 9],
                              np.asarray(kv_b)[:, :, :, 9])


# ---------------------------------------------------------------------------
# tail-only prefill parity (bitwise)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start", [0, 1, 8, 13])
def test_prefill_cached_matches_full_prefill(tm, start):
    """Every cache depth — including start=0 (degenerate: IS a prefill) and
    start=plen-1 (maximal hit, single-token tail, the engine's cap)."""
    cfg, p = tm
    rng = np.random.default_rng(1)
    prompt = np.asarray(toks(rng, (14,)))
    plen = len(prompt)

    l_ref, f_ref, kv_ref = full_prefill(cfg, p, prompt)
    l_c, f_c, kv_c = cached_prefill(cfg, p, prompt, start,
                                    seeded_kv(kv_ref, start))

    np.testing.assert_array_equal(np.asarray(l_c), np.asarray(l_ref))
    # feats row i of the cached run is prompt position start + i
    np.testing.assert_array_equal(np.asarray(f_c)[0, :plen - start],
                                  np.asarray(f_ref)[0, start:plen])
    # the recomputed tail KV rows land bitwise on the full prefill's; the
    # seeded prefix rows pass through untouched
    np.testing.assert_array_equal(np.asarray(kv_c)[:, :, :, :plen],
                                  np.asarray(kv_ref)[:, :, :, :plen])


def test_prefill_cached_cross_request(tm):
    """The engine's actual flow: request A prefills fully and registers its
    blocks; request B (same 9-token prefix, different tail) seeds from A's
    rows and computes only its own tail. Must be invisible vs B's full
    prefill."""
    cfg, p = tm
    rng = np.random.default_rng(2)
    shared = np.asarray(toks(rng, (9,)))
    a = np.concatenate([shared, np.asarray(toks(rng, (6,)))])
    b = np.concatenate([shared, np.asarray(toks(rng, (4,)))])

    _, _, kv_a = full_prefill(cfg, p, a)
    l_ref, f_ref, kv_ref = full_prefill(cfg, p, b)

    l_c, f_c, kv_c = cached_prefill(cfg, p, b, 9, seeded_kv(kv_a, 9))

    np.testing.assert_array_equal(np.asarray(l_c), np.asarray(l_ref))
    np.testing.assert_array_equal(np.asarray(f_c)[0, :len(b) - 9],
                                  np.asarray(f_ref)[0, 9:len(b)])
    np.testing.assert_array_equal(np.asarray(kv_c)[:, :, :, :len(b)],
                                  np.asarray(kv_ref)[:, :, :, :len(b)])


def test_pad_garbage_in_tail_is_invisible(tm):
    """Slots at or past plen - start are PAD: changing them must not perturb
    a single output bit (they sit beyond every row's key_limit)."""
    cfg, p = tm
    rng = np.random.default_rng(3)
    prompt = np.asarray(toks(rng, (12,)))
    _, _, kv_ref = full_prefill(cfg, p, prompt)
    seed = seeded_kv(kv_ref, 6)

    tail = np.full((1, PREFIX_TAIL_PAD), 17, np.int32)
    tail[0, :6] = prompt[6:]
    alt = tail.copy()
    alt[0, 6:] = 233
    args = (jnp.asarray([12], jnp.int32), jnp.asarray([6], jnp.int32), seed)
    l1, f1, k1 = prefill_cached(p, cfg, jnp.asarray(tail), *args)
    l2, f2, k2 = prefill_cached(p, cfg, jnp.asarray(alt), *args)

    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    np.testing.assert_array_equal(np.asarray(f1)[0, :6], np.asarray(f2)[0, :6])
    np.testing.assert_array_equal(np.asarray(k1)[:, :, :, :12],
                                  np.asarray(k2)[:, :, :, :12])
