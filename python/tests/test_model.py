"""L2 target model: shapes, KV-cache serving-path consistency, feature taps."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import S_MAX, TARGETS
from compile.model import (
    init_target,
    prefill,
    target_features,
    target_forward_train,
    target_loss,
    verify,
    zero_kv,
)


@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def test_train_forward_shapes(tm):
    cfg, p = tm
    rng = np.random.default_rng(0)
    t = toks(rng, (3, 20))
    logits = target_forward_train(p, cfg, t)
    assert logits.shape == (3, 20, cfg.vocab)
    loss = target_loss(p, cfg, t)
    assert np.isfinite(float(loss))
    # random init ≈ uniform loss
    assert abs(float(loss) - np.log(cfg.vocab)) < 1.0


def test_feature_taps_shape_and_distinct(tm):
    cfg, p = tm
    rng = np.random.default_rng(1)
    t = toks(rng, (2, 16))
    feats, logits = target_features(p, cfg, t)
    assert feats.shape == (2, 16, 3 * cfg.d_model)
    d = cfg.d_model
    f = np.asarray(feats)
    # the three taps are different layers — they must differ
    assert not np.allclose(f[..., :d], f[..., d:2 * d])
    assert not np.allclose(f[..., d:2 * d], f[..., 2 * d:])


def test_prefill_respects_prompt_len(tm):
    """Padding garbage beyond prompt_len must not affect the last-position
    logits or the features of real positions."""
    cfg, p = tm
    rng = np.random.default_rng(2)
    P = 24
    base = np.asarray(toks(rng, (1, P)))
    a = base.copy()
    b = base.copy()
    b[0, 12:] = 77  # different garbage beyond prompt_len=12
    plen = jnp.asarray([12], jnp.int32)
    kv = zero_kv(cfg, 1)
    la, fa, _ = prefill(p, cfg, jnp.asarray(a), plen, kv)
    lb, fb, _ = prefill(p, cfg, jnp.asarray(b), plen, kv)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(fa)[:, :12], np.asarray(fb)[:, :12], atol=1e-5
    )


def test_prefill_verify_matches_full_forward(tm):
    """The KV-cached serving path (prefill + chained verifies) must produce
    the same logits/features as one full forward — the invariant the whole
    engine rests on."""
    cfg, p = tm
    rng = np.random.default_rng(3)
    plen, k = 18, 5
    seq = np.asarray(toks(rng, (1, plen + 2 * (k + 1))))
    prompt = np.full((1, 24), 0, np.int32)
    prompt[:, :plen] = seq[:, :plen]

    kv = zero_kv(cfg, 1)
    last_logits, feats0, kv = prefill(
        p, cfg, jnp.asarray(prompt), jnp.asarray([plen], jnp.int32), kv)

    # two chained verify calls walking the sequence
    c1 = seq[:, plen:plen + k + 1]
    l1, f1, kv = verify(p, cfg, jnp.asarray(c1), jnp.asarray([plen], jnp.int32), kv)
    c2 = seq[:, plen + k + 1:plen + 2 * (k + 1)]
    l2, f2, kv = verify(
        p, cfg, jnp.asarray(c2), jnp.asarray([plen + k + 1], jnp.int32), kv)

    feats_full, logits_full = target_features(p, cfg, jnp.asarray(seq))
    np.testing.assert_allclose(
        np.asarray(l1[0]), np.asarray(logits_full[0, plen:plen + k + 1]),
        atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(
        np.asarray(f2[0]), np.asarray(feats_full[0, plen + k + 1:plen + 2 * (k + 1)]),
        atol=2e-4, rtol=2e-4)
    # prefill last-position logits match too
    np.testing.assert_allclose(
        np.asarray(last_logits[0]), np.asarray(logits_full[0, plen - 1]),
        atol=2e-4, rtol=2e-4)


def test_verify_partial_accept_overwrite(tm):
    """Rejected-draft KV entries must be safely overwritten by the next
    verify (the overwrite-safety argument in DESIGN.md)."""
    cfg, p = tm
    rng = np.random.default_rng(4)
    plen, k = 16, 4
    prompt = np.zeros((1, 24), np.int32)
    prompt[:, :plen] = np.asarray(toks(rng, (1, plen)))
    kv = zero_kv(cfg, 1)
    _, _, kv = prefill(p, cfg, jnp.asarray(prompt), jnp.asarray([plen], jnp.int32), kv)

    # verify a junk chunk, accept only 1 token (cache_len advances by 2)
    junk = toks(rng, (1, k + 1))
    _, _, kv = verify(p, cfg, junk, jnp.asarray([plen], jnp.int32), kv)
    good = toks(rng, (1, k + 1))
    accepted = 2
    l2, _, kv = verify(p, cfg, good, jnp.asarray([plen + accepted], jnp.int32), kv)

    # reference: full forward over prompt + junk[:accepted] + good
    ref_seq = np.concatenate(
        [prompt[:, :plen], np.asarray(junk)[:, :accepted], np.asarray(good)], axis=1)
    _, logits_full = target_features(p, cfg, jnp.asarray(ref_seq))
    np.testing.assert_allclose(
        np.asarray(l2[0]),
        np.asarray(logits_full[0, plen + accepted:]),
        atol=2e-4, rtol=2e-4)


def test_kv_capacity_asserts():
    cfg = TARGETS["target-m"]
    kv = zero_kv(cfg, 2)
    assert kv.shape == (cfg.n_layers, 2, 2, S_MAX, cfg.n_heads, cfg.head_dim)


def test_all_targets_init():
    for name, cfg in TARGETS.items():
        p = init_target(jax.random.PRNGKey(1), cfg)
        assert p["embed"].shape == (cfg.vocab, cfg.d_model)
        assert len(p["blocks"]) == cfg.n_layers
        lo, mid, hi = cfg.feature_layers
        assert lo < cfg.n_layers and hi == cfg.n_layers - 1
        assert len(set(cfg.feature_layers)) == 3, name
