"""Synthetic corpora + Figure-1 length model."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.configs import BOS_ID, VOCAB
from compile import data as data_mod


@settings(max_examples=20, deadline=None)
@given(regime=st.sampled_from(list(data_mod.REGIMES)),
       length=st.integers(1, 200), seed=st.integers(0, 999))
def test_sample_seq_contract(regime, length, seed):
    r = data_mod.PhraseRegime(regime)
    rng = np.random.default_rng(seed)
    s = r.sample_seq(length, rng)
    assert len(s) == length
    assert s[0] == BOS_ID
    assert (s[1:] >= 4).all() and (s < VOCAB).all()


def test_regimes_deterministic_across_instances():
    a = data_mod.PhraseRegime("humaneval")
    b = data_mod.PhraseRegime("humaneval")
    assert all((x == y).all() for x, y in zip(a.phrases, b.phrases))
    assert (a.succ == b.succ).all()
    np.testing.assert_allclose(a.probs, b.probs)


def test_regime_entropy_ordering():
    """Regime predictability must order humaneval > gsm8k > mtbench (the
    paper's per-dataset AL ordering driver)."""
    def mean_boundary_entropy(r):
        p = r.probs
        return float(-(p * np.log(p + 1e-9)).sum(axis=1).mean())
    hs = {n: mean_boundary_entropy(data_mod.PhraseRegime(n)) for n in data_mod.REGIMES}
    assert hs["humaneval"] < hs["gsm8k"] < hs["mtbench"], hs


def test_phrase_lengths_ordering():
    ls = {
        n: np.mean([len(p) for p in data_mod.PhraseRegime(n).phrases])
        for n in data_mod.REGIMES
    }
    assert ls["humaneval"] > ls["gsm8k"] > ls["mtbench"], ls


def test_eval_prompts_disjoint_from_training_stream():
    prompts = data_mod.eval_prompts("gsm8k", 8, 24, seed=42)
    assert prompts.shape == (8, 24)
    # different seeds -> different prompt sets
    other = data_mod.eval_prompts("gsm8k", 8, 24, seed=43)
    assert (prompts != other).any()


def test_export_tables_roundtrip():
    r = data_mod.PhraseRegime("mtbench")
    t = r.export_tables()
    assert t["name"] == "mtbench"
    assert len(t["phrases"]) == len(r.phrases)
    assert all(isinstance(x, int) for x in t["phrases"][0])


def test_training_batch_mixture():
    regimes = {n: data_mod.PhraseRegime(n) for n in data_mod.REGIMES}
    rng = np.random.default_rng(0)
    b = data_mod.training_batch(regimes, 16, 64, rng)
    assert b.shape == (16, 64)
    assert (b[:, 0] == BOS_ID).all()


def test_fig1_length_model_quantiles():
    rng = np.random.default_rng(0)
    xs = [data_mod.sample_paper_length(rng) for _ in range(40_000)]
    stats = data_mod.length_distribution_stats(xs)
    assert abs(stats["median"] - 3891) / 3891 < 0.2, stats
    assert abs(stats["p90"] - 10800) / 10800 < 0.25, stats
    assert abs(stats["p99"] - 20000) / 20000 < 0.3, stats
