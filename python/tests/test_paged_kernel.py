"""In-place paged attention: bitwise parity against the gather-dense path.

ISSUE 9's tentpole contract: the Pallas paged-attention kernel attends the
block pool *in place* through the block table — no `paged_gather`
densification — and the `*_inplace` verify twins lowered on it must produce
BITWISE-equal logits/feats to the legacy gather twins on the same logical
cache state, across chain / static-tree / dynamic-tree speculation. That
equality is what lets aot.py swap the lowered path under the same executable
names with zero Rust-side changes, and what licenses the engine's
device-commit byte-parity integration test.

Pool-parity caveat: the in-place scatter only writes chunk positions, while
the gather path rewrites every covered block; the two output pools agree on
all table-addressed blocks and may differ only in the reserved null block 0
(inactive-row garbage, never attended).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import (
    COMMIT_PLAN_ROWS, KV_BLOCK_SIZE, S_MAX, TARGETS, kv_blocks_per_slot,
    num_kv_blocks,
)
from compile.kernels.paged_attention import paged_attention
from compile.kernels.ref import ref_paged_attention
from compile.masks import paged_logical_view, tree_ancestor_mask, tree_depths
from compile.model import (
    commit_path_paged, init_target, paged_scatter, prefill, verify_paged,
    verify_paged_inplace, verify_tree_dyn_paged, verify_tree_dyn_paged_inplace,
    verify_tree_paged, verify_tree_paged_inplace, zero_kv, zero_kv_paged,
)

M = kv_blocks_per_slot()
BS = KV_BLOCK_SIZE


@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def fresh_table(batch, rng=None, shuffle=False):
    ids = np.arange(1, batch * M + 1)
    if shuffle:
        ids = rng.permutation(ids)
    return jnp.asarray(ids.reshape(batch, M), jnp.int32)


def pool_from_dense(cfg, dense, table):
    pool = zero_kv_paged(cfg, num_kv_blocks(dense.shape[2]), KV_BLOCK_SIZE)
    return paged_scatter(pool, table, dense)


def prefilled(cfg, params, rng, batch=1, plen=14, same_prompt=False):
    prompt = np.zeros((batch, 24), np.int32)
    row = np.asarray(toks(rng, (1, plen)))
    for i in range(batch):
        prompt[i, :plen] = row if same_prompt else np.asarray(
            toks(rng, (1, plen)))
    kv = zero_kv(cfg, batch)
    _, _, kv = prefill(params, cfg, jnp.asarray(prompt),
                       jnp.asarray([plen] * batch, jnp.int32), kv)
    return kv, plen


# ---------------------------------------------------------------------------
# kernel vs numpy oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,t", [(1, 6), (2, 8), (4, 9)])
def test_kernel_matches_ref(tm, batch, t):
    cfg, _ = tm
    rng = np.random.default_rng(10 + batch)
    nb = num_kv_blocks(batch)
    table = fresh_table(batch, rng, shuffle=True)
    q = jnp.asarray(rng.normal(size=(batch, cfg.n_heads, t, cfg.head_dim)),
                    jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, BS, cfg.n_heads, cfg.head_dim)),
                     jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, BS, cfg.n_heads, cfg.head_dim)),
                     jnp.float32)
    # causal-ish random additive bias with some -inf structure
    bias = np.where(rng.random((batch, 1, t, M * BS)) < 0.3, -1e9, 0.0)
    bias = jnp.asarray(bias, jnp.float32)
    out = paged_attention(q, kp, vp, table, bias)
    ref = ref_paged_attention(q, kp, vp, table, bias)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_kernel_matches_ref_shared_bias(tm):
    """[1,1,T,S] bias broadcasts across the batch identically."""
    cfg, _ = tm
    rng = np.random.default_rng(20)
    nb = num_kv_blocks(2)
    table = fresh_table(2, rng, shuffle=True)
    q = jnp.asarray(rng.normal(size=(2, cfg.n_heads, 7, cfg.head_dim)),
                    jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nb, BS, cfg.n_heads, cfg.head_dim)),
                     jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nb, BS, cfg.n_heads, cfg.head_dim)),
                     jnp.float32)
    bias = jnp.asarray(
        np.where(rng.random((1, 1, 7, M * BS)) < 0.3, -1e9, 0.0), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(paged_attention(q, kp, vp, table, bias)),
        np.asarray(ref_paged_attention(q, kp, vp, table, bias)))


# ---------------------------------------------------------------------------
# in-place verify twins vs gather twins (bitwise)
# ---------------------------------------------------------------------------

def test_verify_inplace_matches_gather_chain(tm):
    cfg, p = tm
    rng = np.random.default_rng(2)
    kv, plen = prefilled(cfg, p, rng, batch=2)
    table = fresh_table(2, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    chunk = toks(rng, (2, 6))
    clen = jnp.asarray([plen, plen], jnp.int32)

    l_g, f_g, pool_g = verify_paged(p, cfg, chunk, clen, table, pool)
    l_i, f_i, pool_i = verify_paged_inplace(p, cfg, chunk, clen, table, pool)

    np.testing.assert_array_equal(np.asarray(l_i), np.asarray(l_g))
    np.testing.assert_array_equal(np.asarray(f_i), np.asarray(f_g))
    # pools agree on every table-addressed block (null block 0 exempt)
    np.testing.assert_array_equal(
        np.asarray(pool_i)[:, :, 1:], np.asarray(pool_g)[:, :, 1:])


def test_verify_inplace_matches_gather_tree(tm):
    cfg, p = tm
    rng = np.random.default_rng(3)
    kv, plen = prefilled(cfg, p, rng)
    table = fresh_table(1, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    widths = [3, 2, 1]
    n = sum(widths)
    chunk = toks(rng, (1, n + 1))
    clen = jnp.asarray([plen], jnp.int32)
    mask = jnp.asarray(tree_ancestor_mask(widths), jnp.int32)
    depths = tuple(tree_depths(widths))

    l_g, f_g, pool_g = verify_tree_paged(p, cfg, chunk, clen, table, pool,
                                         mask, depths)
    l_i, f_i, pool_i = verify_tree_paged_inplace(p, cfg, chunk, clen, table,
                                                 pool, mask, depths)

    np.testing.assert_array_equal(np.asarray(l_i), np.asarray(l_g))
    np.testing.assert_array_equal(np.asarray(f_i), np.asarray(f_g))
    np.testing.assert_array_equal(
        np.asarray(pool_i)[:, :, 1:], np.asarray(pool_g)[:, :, 1:])


def test_verify_inplace_matches_gather_dyn(tm):
    """Dynamic-tree twin: per-batch runtime mask + depth offsets, rows with
    different active-node subsets (row 1's tail is disabled)."""
    cfg, p = tm
    rng = np.random.default_rng(4)
    kv, plen = prefilled(cfg, p, rng, batch=2)
    table = fresh_table(2, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    widths = [3, 2, 1]
    n = sum(widths)
    chunk = toks(rng, (2, n + 1))
    clen = jnp.asarray([plen, plen], jnp.int32)
    base = np.asarray(tree_ancestor_mask(widths), np.int32)
    depths = np.asarray(tree_depths(widths), np.int32)
    mask = np.stack([base, base])
    mask[1, n:, :] = 0
    mask[1, :, n:] = 0
    mask[1, n, n] = 1          # keep the disabled node self-visible
    doffs = np.stack([depths, depths]).astype(np.int32)
    tmask = jnp.asarray(mask, jnp.int32)
    offs = jnp.asarray(doffs, jnp.int32)

    l_g, f_g, pool_g = verify_tree_dyn_paged(p, cfg, chunk, clen, table, pool,
                                             tmask, offs)
    l_i, f_i, pool_i = verify_tree_dyn_paged_inplace(
        p, cfg, chunk, clen, table, pool, tmask, offs)

    np.testing.assert_array_equal(np.asarray(l_i), np.asarray(l_g))
    np.testing.assert_array_equal(np.asarray(f_i), np.asarray(f_g))
    np.testing.assert_array_equal(
        np.asarray(pool_i)[:, :, 1:], np.asarray(pool_g)[:, :, 1:])


def test_multistep_decode_parity_inplace(tm):
    """Thread the pool through several greedy steps: the in-place and gather
    paths must pick identical argmax tokens at every step."""
    cfg, p = tm
    rng = np.random.default_rng(5)
    kv, plen = prefilled(cfg, p, rng)
    table = fresh_table(1, rng, shuffle=True)
    pool_g = pool_from_dense(cfg, kv, table)
    pool_i = pool_g
    k = 3
    clen_v, tok_g, tok_i = plen, 5, 5
    for step in range(4):
        chunk = np.full((1, k + 1), 4 + step, np.int32)
        clen = jnp.asarray([clen_v], jnp.int32)
        chunk[0, 0] = tok_g
        lg, _, pool_g = verify_paged(p, cfg, jnp.asarray(chunk), clen, table,
                                     pool_g)
        chunk[0, 0] = tok_i
        li, _, pool_i = verify_paged_inplace(p, cfg, jnp.asarray(chunk), clen,
                                             table, pool_i)
        np.testing.assert_array_equal(np.asarray(li), np.asarray(lg))
        tok_g = int(np.argmax(np.asarray(lg)[0, 0]))
        tok_i = int(np.argmax(np.asarray(li)[0, 0]))
        assert tok_g == tok_i, f"step {step}: {tok_g} != {tok_i}"
        clen_v += 1


def test_inplace_preserves_cow_shared_prefix_blocks(tm):
    """Prefix-cache COW sharing: two rows share a fully committed prefix
    block; the in-place scatter writes only chunk positions, so the shared
    block's bytes must be untouched — that is what makes in-place verify safe
    over COW-shared tables without copy-up. (The gather path would rewrite
    the shared block, which is why the engine copies-up before dense
    scatter.) Both rows carry the same prompt, so logits must match the
    exclusive-table baseline bitwise."""
    cfg, p = tm
    rng = np.random.default_rng(6)
    plen = BS  # exactly one fully committed block — shareable
    kv, _ = prefilled(cfg, p, rng, batch=2, plen=plen, same_prompt=True)
    excl = fresh_table(2)
    pool = pool_from_dense(cfg, kv, excl)
    # row 1's first (prefix) block aliases row 0's; chunk lands in block 1
    shared = np.asarray(excl).copy()
    shared[1, 0] = shared[0, 0]
    shared = jnp.asarray(shared, jnp.int32)
    chunk = toks(rng, (2, 6))
    chunk = jnp.asarray(np.stack([np.asarray(chunk)[0]] * 2), jnp.int32)
    clen = jnp.asarray([plen, plen], jnp.int32)

    l_ref, _, _ = verify_paged_inplace(p, cfg, chunk, clen, excl, pool)
    l_cow, _, pool_cow = verify_paged_inplace(p, cfg, chunk, clen, shared,
                                              pool)

    np.testing.assert_array_equal(np.asarray(l_cow), np.asarray(l_ref))
    sb = int(np.asarray(shared)[0, 0])
    np.testing.assert_array_equal(
        np.asarray(pool_cow)[:, :, sb], np.asarray(pool)[:, :, sb])


def test_logical_view_parity_after_inplace(tm):
    """The in-place written-back pool holds the same logical cache as the
    gather path everywhere the cache is valid."""
    cfg, p = tm
    rng = np.random.default_rng(7)
    kv, plen = prefilled(cfg, p, rng, batch=2)
    table = fresh_table(2, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    chunk = toks(rng, (2, 6))
    clen = jnp.asarray([plen, plen], jnp.int32)
    _, _, pool_g = verify_paged(p, cfg, chunk, clen, table, pool)
    _, _, pool_i = verify_paged_inplace(p, cfg, chunk, clen, table, pool)
    np.testing.assert_array_equal(
        paged_logical_view(pool_i, table)[:, :, :, :plen + 6],
        paged_logical_view(pool_g, table)[:, :, :, :plen + 6])


# ---------------------------------------------------------------------------
# device commit executable
# ---------------------------------------------------------------------------

def test_commit_path_paged_matches_sequential_copies(tm):
    """The single gather-then-scatter must equal applying the plan rows one
    by one (the host `apply_path_copies` semantics): `plan_path_commit`
    plans are ascending with src > dst within a slot, so no source row is
    clobbered before it is read."""
    cfg, _ = tm
    rng = np.random.default_rng(8)
    nb = num_kv_blocks(2)
    pool = np.asarray(rng.normal(
        size=(cfg.n_layers, 2, nb, BS, cfg.n_heads, cfg.head_dim)),
        np.float32)
    # a non-aligned accepted path: pull logical rows base+{2,4,5} down to
    # base+{1,2,3} inside block 3, plus a cross-block move 5->4
    plan = np.zeros((COMMIT_PLAN_ROWS, 4), np.int32)
    plan[:4] = [[3, 2, 3, 1], [3, 4, 3, 2], [3, 5, 3, 3], [5, 0, 4, 15]]

    ref = pool.copy()
    for sb, so, db, do in plan[:4]:
        ref[:, :, db, do] = ref[:, :, sb, so]
    # padding rows are inert null self-copies — block 0 copies onto itself

    out = np.asarray(commit_path_paged(jnp.asarray(plan), jnp.asarray(pool)))
    np.testing.assert_array_equal(out, ref)


def test_commit_path_paged_all_padding_is_identity(tm):
    cfg, _ = tm
    rng = np.random.default_rng(9)
    nb = num_kv_blocks(1)
    pool = np.asarray(rng.normal(
        size=(cfg.n_layers, 2, nb, BS, cfg.n_heads, cfg.head_dim)),
        np.float32)
    plan = np.zeros((COMMIT_PLAN_ROWS, 4), np.int32)
    out = np.asarray(commit_path_paged(jnp.asarray(plan), jnp.asarray(pool)))
    np.testing.assert_array_equal(out, pool)
