"""Dynamic-tree speculation: confidence selection, subset masks, scored
drafting, and max-shape-envelope verification with runtime topologies.

The load-bearing properties:
  * reference selection (masks.tree_select_nodes) is always ancestor-closed
    and — under monotone drafter scores — exactly the global top-budget;
  * the compacted subset mask/depths (masks.tree_subset_*) are the envelope
    ancestor mask gathered over [root] + selected, zero elsewhere — the
    numpy reference the Rust masking/dynamic.rs property tests mirror;
  * draft_pe_tree(return_logp=True) returns the same tokens plus joint
    log-probabilities that really are the per-level log-softmax terms summed
    along each root path (monotone non-increasing down every path);
  * verify_tree_dyn with every node selected reproduces verify_tree (the
    degenerate case that licenses dynamic mode), per-subset path consistency
    holds (an active slot's logits equal a linear verify over its compacted
    root path), and inactive tail slots neither perturb active rows nor leak
    into them.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import (
    TARGETS, TREE_DYN_ENVELOPE, TREE_DYN_ENVELOPES, DEFAULT_TREE_BUDGET,
    get_drafter,
)
from compile.drafter import _pe_depth_logits, draft_pe_tree, init_drafter
from compile.masks import (
    tree_ancestor_mask,
    tree_depths,
    tree_parents,
    tree_select_nodes,
    tree_subset_depths,
    tree_subset_mask,
    tree_topology_id,
)
from compile.model import init_target, prefill, verify, verify_tree, verify_tree_dyn, zero_kv


# ---------------------------------------------------------------------------
# selection reference
# ---------------------------------------------------------------------------

def monotone_joint(widths, rng):
    """Random drafter-shaped joints: child = parent + level term (<= 0)."""
    parents = tree_parents(widths)
    joint = np.zeros(len(parents))
    for i, p in enumerate(parents, start=1):
        joint[i - 1] = -rng.uniform(0.01, 4.0) + (0.0 if p == 0 else joint[p - 1])
    return joint


def test_registry_is_well_formed():
    assert DEFAULT_TREE_BUDGET == 8
    assert sum(TREE_DYN_ENVELOPE) == 13
    for topo in TREE_DYN_ENVELOPES:
        assert tree_topology_id(topo)
    assert tree_topology_id(TREE_DYN_ENVELOPE) == "w4x4x2x2x1"


def test_select_nodes_chain_envelope_is_prefix():
    joint = np.array([-1.0, -2.0, -3.0, -4.0, -5.0])
    for b in range(1, 6):
        assert tree_select_nodes([1] * 5, joint, b) == list(range(1, b + 1))


def test_select_nodes_prefers_confident_branch():
    # widths [2, 2]: parents [0, 0, 1, 2]; node 2's branch dominates
    joint = np.array([-5.0, -0.1, -9.0, -0.2])
    assert tree_select_nodes([2, 2], joint, 2) == [2, 4]
    assert tree_select_nodes([2, 2], joint, 3) == [1, 2, 4]


def test_select_nodes_always_ancestor_closed():
    rng = np.random.default_rng(0)
    for _ in range(60):
        levels = rng.integers(1, 5)
        widths = list(rng.integers(1, 4, size=levels))
        parents = tree_parents(widths)
        n = len(parents)
        # adversarial scores, including NaN and non-monotone
        joint = rng.normal(size=n)
        joint[rng.random(n) < 0.1] = np.nan
        budget = int(rng.integers(1, n + 2))
        sel = tree_select_nodes(widths, joint, budget)
        assert sel == sorted(sel)
        assert len(sel) == min(budget, n)
        for node in sel:
            p = parents[node - 1]
            assert p == 0 or p in sel, (widths, joint, sel)


def test_select_nodes_is_global_topn_under_monotone_scores():
    rng = np.random.default_rng(1)
    for _ in range(40):
        levels = rng.integers(1, 5)
        widths = list(rng.integers(1, 4, size=levels))
        joint = monotone_joint(widths, rng)
        n = len(joint)
        budget = int(rng.integers(1, n + 1))
        sel = tree_select_nodes(widths, joint, budget)
        want = sorted(np.argsort(-joint, kind="stable")[:budget] + 1)
        assert sel == [int(w) for w in want], (widths, joint)


# ---------------------------------------------------------------------------
# subset mask / depths references
# ---------------------------------------------------------------------------

def test_subset_mask_is_gathered_envelope_mask():
    rng = np.random.default_rng(2)
    for _ in range(40):
        levels = rng.integers(1, 5)
        widths = list(rng.integers(1, 4, size=levels))
        joint = monotone_joint(widths, rng)
        n = len(joint)
        budget = int(rng.integers(1, n + 1))
        sel = tree_select_nodes(widths, joint, budget)
        full = tree_ancestor_mask(widths)
        sub = tree_subset_mask(widths, sel)
        assert sub.shape == full.shape
        slots = [0] + sel
        m = len(slots)
        np.testing.assert_array_equal(sub[:m, :m], full[np.ix_(slots, slots)])
        assert not sub[m:, :].any() and not sub[:, m:].any()


def test_subset_mask_full_selection_is_envelope_mask():
    widths = [3, 2, 1, 1, 1]
    every = list(range(1, len(tree_parents(widths)) + 1))
    np.testing.assert_array_equal(
        tree_subset_mask(widths, every), tree_ancestor_mask(widths))
    assert tree_subset_depths(widths, every) == tree_depths(widths)


def test_subset_depths_follow_envelope_depths():
    # widths [2, 2]: selecting {2, 4} compacts to depths [0, 1, 2, 0, 0]
    assert tree_subset_depths([2, 2], [2, 4]) == [0, 1, 2, 0, 0]


# ---------------------------------------------------------------------------
# scored drafting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def dm(tm):
    tcfg, _ = tm
    dcfg = get_drafter("target-m-pe4")
    params = init_drafter(jax.random.PRNGKey(3), dcfg, tcfg)
    return dcfg, tcfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def draft_inputs(tcfg, rng, c=8):
    ct = toks(rng, (2, c))
    cf = jnp.asarray(rng.normal(size=(2, c, tcfg.feature_dim)), jnp.float32)
    p0 = jnp.asarray([c - 1, c + 3], jnp.int32)
    return ct, cf, p0


def test_scored_draft_tokens_match_unscored(dm):
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(10)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = TREE_DYN_ENVELOPE
    plain = draft_pe_tree(dp, dcfg, ct, cf, p0, widths, attn_impl="jnp")
    tokens, joint = draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                                  attn_impl="jnp", return_logp=True)
    # bitwise: scoring must not perturb the drafted tokens (the Rust
    # degenerate-parity test swaps drafter executables and expects identity)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(tokens))
    assert np.asarray(joint).shape == (2, sum(widths))


def test_scored_draft_joint_is_path_sum_of_level_logps(dm):
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(11)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = (3, 2, 1)
    tokens, joint = draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                                  attn_impl="jnp", return_logp=True)
    tokens, joint = np.asarray(tokens), np.asarray(joint)
    level_logits = np.asarray(_pe_depth_logits(dp, dcfg, ct, cf, p0,
                                               len(widths), attn_impl="jnp"))
    logp = level_logits - np.log(
        np.exp(level_logits - level_logits.max(-1, keepdims=True)).sum(-1, keepdims=True)
    ) - level_logits.max(-1, keepdims=True)
    parents = tree_parents(list(widths))
    depths = tree_depths(list(widths))
    for b in range(tokens.shape[0]):
        for i, p in enumerate(parents, start=1):
            own = logp[b, depths[i] - 1, tokens[b, i - 1]]
            want = own + (0.0 if p == 0 else joint[b, p - 1])
            np.testing.assert_allclose(joint[b, i - 1], want, atol=1e-5, rtol=1e-5)


def test_scored_draft_joint_is_monotone_down_every_path(dm):
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(12)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = TREE_DYN_ENVELOPE
    _, joint = draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                             attn_impl="jnp", return_logp=True)
    joint = np.asarray(joint)
    parents = tree_parents(list(widths))
    for b in range(joint.shape[0]):
        for i, p in enumerate(parents, start=1):
            if p != 0:
                assert joint[b, i - 1] <= joint[b, p - 1] + 1e-6


def _conditional_q(joint_row, parents):
    """The Rust masking/dynamic.rs `conditional_q` reference: per-node
    conditional draft probability recovered from joint path scores,
    q = exp(joint - parent joint), NaN -> 0, clamped to [0, 1]."""
    q = np.zeros(len(parents))
    for i, p in enumerate(parents, start=1):
        base = 0.0 if p == 0 else joint_row[p - 1]
        q[i - 1] = np.exp(joint_row[i - 1] - base)
    return np.clip(np.nan_to_num(q, nan=0.0), 0.0, 1.0)


def test_conditional_q_recovers_level_softmax_probability(dm):
    """The engine's calibration signal: exp(joint - parent joint) must be the
    drafter's own per-level softmax probability of the drafted token — a
    genuine probability in (0, 1], exactly what PolicyMetrics.record_draft_q
    accumulates against acceptance outcomes."""
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(17)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = (3, 2, 1)
    tokens, joint = draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                                  attn_impl="jnp", return_logp=True)
    tokens, joint = np.asarray(tokens), np.asarray(joint)
    level_logits = np.asarray(_pe_depth_logits(dp, dcfg, ct, cf, p0,
                                               len(widths), attn_impl="jnp"))
    mx = level_logits.max(-1, keepdims=True)
    logp = level_logits - mx - np.log(
        np.exp(level_logits - mx).sum(-1, keepdims=True))
    parents = tree_parents(list(widths))
    depths = tree_depths(list(widths))
    for b in range(tokens.shape[0]):
        q = _conditional_q(joint[b], parents)
        assert ((q > 0.0) & (q <= 1.0)).all(), q
        for i in range(1, len(parents) + 1):
            want = np.exp(logp[b, depths[i] - 1, tokens[b, i - 1]])
            np.testing.assert_allclose(q[i - 1], want, atol=1e-5, rtol=1e-4,
                                       err_msg=f"node {i}")


def test_conditional_q_non_increasing_in_rank_within_level(dm):
    """Levels draft the depth's top-w tokens in rank order, so the recovered
    conditional q must be non-increasing across each level's nodes — the
    property that makes q a usable confidence ordering for calibration."""
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(18)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = TREE_DYN_ENVELOPE
    _, joint = draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                             attn_impl="jnp", return_logp=True)
    joint = np.asarray(joint)
    parents = tree_parents(list(widths))
    for b in range(joint.shape[0]):
        q = _conditional_q(joint[b], parents)
        off = 0
        for w in widths:
            level = q[off:off + w]
            assert (np.diff(level) <= 1e-6).all(), (w, level)
            off += w


# ---------------------------------------------------------------------------
# envelope verification with runtime topology
# ---------------------------------------------------------------------------

def prefilled(cfg, params, rng, plen=14):
    prompt = np.zeros((1, 24), np.int32)
    prompt[:, :plen] = np.asarray(toks(rng, (1, plen)))
    kv = zero_kv(cfg, 1)
    _, _, kv = prefill(params, cfg, jnp.asarray(prompt),
                       jnp.asarray([plen], jnp.int32), kv)
    return kv, plen


def test_verify_tree_dyn_full_selection_equals_verify_tree(tm):
    """Degenerate case: every envelope node selected -> the runtime-topology
    executable must reproduce the static tree verify."""
    cfg, p = tm
    rng = np.random.default_rng(13)
    kv, plen = prefilled(cfg, p, rng)
    widths = [2, 2, 1]
    n = len(tree_parents(widths))
    chunk = toks(rng, (1, n + 1))
    clen = jnp.asarray([plen], jnp.int32)
    mask = jnp.asarray(tree_ancestor_mask(widths), jnp.int32)
    depths = tuple(tree_depths(widths))
    l_ref, f_ref, kv_ref = verify_tree(p, cfg, chunk, clen, kv, mask, depths)

    every = list(range(1, n + 1))
    mask_b = jnp.asarray(tree_subset_mask(widths, every), jnp.int32)[None]
    depths_b = jnp.asarray([tree_subset_depths(widths, every)], jnp.int32)
    l_dyn, f_dyn, kv_dyn = verify_tree_dyn(p, cfg, chunk, clen, kv, mask_b,
                                           depths_b)
    # BITWISE: the per-batch mask/depth plumbing feeds the identical chunk
    # forward, so the degenerate case is exact — the engine-level byte
    # parity (rust/tests/integration_tree_dyn.rs) rests on this
    np.testing.assert_array_equal(np.asarray(l_dyn), np.asarray(l_ref))
    np.testing.assert_array_equal(np.asarray(f_dyn), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(kv_dyn), np.asarray(kv_ref))


def test_verify_tree_dyn_subset_rows_match_linear_path_verify(tm):
    """Path consistency per subset: active compacted slot j's logits equal a
    chained verify over [root] + its compacted path tokens."""
    cfg, p = tm
    rng = np.random.default_rng(14)
    kv, plen = prefilled(cfg, p, rng)
    widths = [2, 2]
    # selection {2, 4}: node 4 is node 2's child -> compacted chain 0->1->2
    sel = [2, 4]
    n = len(tree_parents(widths))
    node_toks = np.asarray(toks(rng, (1, n)))
    chunk = np.zeros((1, n + 1), np.int32)
    chunk[0, 0] = int(toks(rng, (1, 1))[0, 0])
    for j, node in enumerate(sel):
        chunk[0, 1 + j] = node_toks[0, node - 1]
    clen = jnp.asarray([plen], jnp.int32)
    mask_b = jnp.asarray(tree_subset_mask(widths, sel), jnp.int32)[None]
    depths_b = jnp.asarray([tree_subset_depths(widths, sel)], jnp.int32)
    l_dyn, _, _ = verify_tree_dyn(p, cfg, jnp.asarray(chunk), clen, kv,
                                  mask_b, depths_b)
    # compacted slots form a chain here: slot m's path is slots 0..m
    for m in range(len(sel) + 1):
        lin = jnp.asarray(chunk[:, :m + 1], jnp.int32)
        l_lin, _, _ = verify(p, cfg, lin, clen, kv)
        np.testing.assert_allclose(
            np.asarray(l_dyn[0, m]), np.asarray(l_lin[0, m]),
            atol=2e-4, rtol=2e-4,
            err_msg=f"compacted slot {m} diverges from linear verify")


def test_verify_tree_dyn_inactive_tail_does_not_perturb_active_rows(tm):
    """PAD tail slots are inert: mutating their tokens must not change any
    active row's logits (they are masked out of every active row's keys)."""
    cfg, p = tm
    rng = np.random.default_rng(15)
    kv, plen = prefilled(cfg, p, rng)
    widths = [2, 2]
    sel = [1, 3]
    n = len(tree_parents(widths))
    a = np.asarray(toks(rng, (1, n + 1)))
    b = a.copy()
    b[0, len(sel) + 1:] = (a[0, len(sel) + 1:] + 77) % 250 + 4  # mutate tail
    clen = jnp.asarray([plen], jnp.int32)
    mask_b = jnp.asarray(tree_subset_mask(widths, sel), jnp.int32)[None]
    depths_b = jnp.asarray([tree_subset_depths(widths, sel)], jnp.int32)
    la, _, _ = verify_tree_dyn(p, cfg, jnp.asarray(a), clen, kv, mask_b, depths_b)
    lb, _, _ = verify_tree_dyn(p, cfg, jnp.asarray(b), clen, kv, mask_b, depths_b)
    for j in range(len(sel) + 1):
        np.testing.assert_allclose(np.asarray(la[0, j]), np.asarray(lb[0, j]),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {j}")


def test_verify_tree_dyn_batches_independent_subsets(tm):
    """Each batch row carries its OWN subset mask/depths: a [B=2] call with
    different selections must reproduce the two [B=1] calls row-for-row."""
    cfg, p = tm
    rng = np.random.default_rng(16)
    widths = [2, 2]
    n = len(tree_parents(widths))
    sels = [[1, 3], [2, 4]]
    plen = 14
    prompt = np.zeros((2, 24), np.int32)
    prompt[:, :plen] = np.asarray(toks(rng, (2, plen)))
    kv2 = zero_kv(cfg, 2)
    _, _, kv2 = prefill(p, cfg, jnp.asarray(prompt),
                        jnp.asarray([plen, plen], jnp.int32), kv2)
    chunk2 = np.asarray(toks(rng, (2, n + 1)))
    clen2 = jnp.asarray([plen, plen], jnp.int32)
    mask2 = jnp.asarray(
        np.stack([tree_subset_mask(widths, s) for s in sels]), jnp.int32)
    depths2 = jnp.asarray([tree_subset_depths(widths, s) for s in sels],
                          jnp.int32)
    l2, f2, _ = verify_tree_dyn(p, cfg, jnp.asarray(chunk2), clen2, kv2,
                                mask2, depths2)
    for b, s in enumerate(sels):
        kv1 = zero_kv(cfg, 1)
        _, _, kv1 = prefill(p, cfg, jnp.asarray(prompt[b:b + 1]),
                            jnp.asarray([plen], jnp.int32), kv1)
        mask1 = jnp.asarray(tree_subset_mask(widths, s), jnp.int32)[None]
        depths1 = jnp.asarray([tree_subset_depths(widths, s)], jnp.int32)
        l1, f1, _ = verify_tree_dyn(p, cfg, jnp.asarray(chunk2[b:b + 1]),
                                    jnp.asarray([plen], jnp.int32), kv1,
                                    mask1, depths1)
        np.testing.assert_allclose(np.asarray(l2[b]), np.asarray(l1[0]),
                                   atol=2e-4, rtol=2e-4, err_msg=f"row {b}")
        np.testing.assert_allclose(np.asarray(f2[b]), np.asarray(f1[0]),
                                   atol=2e-4, rtol=2e-4, err_msg=f"row {b}")
