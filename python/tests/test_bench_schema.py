"""The BENCH_<pr>.json perf-trajectory files committed at the repo root:
schema validity + full-matrix coverage, checked from the Python side (the
Rust parser in rust/src/bench/schema.rs is the normative validator; this
test keeps the COMMITTED files honest in environments that only run
pytest). Mirrors the semantics documented in ARCHITECTURE.md."""

import json
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[2]
BENCH_FILES = sorted(REPO.glob("BENCH_*.json"))

SHAPES = {"chain", "tree", "dyn", "adaptive"}
STATIC_SHAPES = {"chain", "tree", "dyn"}
CACHES = {"dense", "paged", "prefix"}
LOADS = {"closed", "open", "adaptive"}
STATIC_LOADS = {"closed", "open"}

REPORT_KEYS = ["schema_version", "pr", "git_rev", "created_unix", "suite",
               "target", "dataset", "seed", "note", "cells"]
CONFIG_KEYS = ["shape", "cache", "drafter", "policy", "load", "concurrency",
               "rate_rps", "requests", "max_new", "seed", "deterministic"]
METRIC_KEYS = ["requests_finished", "tokens_emitted", "iterations",
               "acceptance_length", "mean_occupancy", "mean_block_occupancy",
               "blocks_peak", "admissions_blocked", "mean_active_nodes",
               "downloads_per_step", "uploads_per_step", "download_bytes",
               "upload_bytes", "kv_downloads", "kv_uploads",
               "device_path_commits", "per_policy"]
POLICY_CELL_KEYS = ["policy", "iterations", "acceptance_length"]
TIMING_KEYS = ["otps", "ttft_p50_us", "ttft_p99_us", "tpot_p50_us",
               "tpot_p99_us", "latency_p50_us", "latency_p99_us", "wall_ms"]


def cell_id(cfg):
    """The Rust CellConfig::id derivation (rate formatted via {:g} to match
    Rust's shortest f64 Display)."""
    if cfg["load"] in ("open", "adaptive"):
        return (f"{cfg['shape']}/{cfg['cache']}/{cfg['drafter']}"
                f"/{cfg['load']}-c{cfg['concurrency']}-r{cfg['rate_rps']:g}")
    return f"{cfg['shape']}/{cfg['cache']}/{cfg['drafter']}/closed-c{cfg['concurrency']}"


def test_trajectory_files_exist():
    names = {p.name for p in BENCH_FILES}
    assert "BENCH_6.json" in names
    assert "BENCH_8.json" in names
    assert "BENCH_9.json" in names
    assert "BENCH_10.json" in names
    assert "BENCH_baseline.json" in names


@pytest.mark.parametrize("path", BENCH_FILES, ids=lambda p: p.name)
def test_schema_valid(path):
    r = json.loads(path.read_text())
    assert r["schema_version"] == 3
    assert list(r.keys()) == REPORT_KEYS
    assert r["suite"] in ("smoke", "full")
    ids = set()
    for cell in r["cells"]:
        assert list(cell.keys()) == ["id", "config", "metrics", "timing"]
        cfg, met, tim = cell["config"], cell["metrics"], cell["timing"]
        assert list(cfg.keys()) == CONFIG_KEYS
        assert list(met.keys()) == METRIC_KEYS
        assert list(tim.keys()) == TIMING_KEYS
        assert cfg["shape"] in SHAPES
        assert cfg["cache"] in CACHES
        assert cfg["load"] in LOADS
        # the adaptive column is coherent: shape, load, drafter, and policy
        # all say "the controller owns this cell" together or not at all
        assert (cfg["shape"] == "adaptive") == (cfg["load"] == "adaptive")
        if cfg["load"] == "adaptive":
            assert cfg["drafter"] == "auto"
            assert cfg["policy"] == "adaptive"
        # closed-loop cells are the deterministic ones, exactly
        assert cfg["deterministic"] == (cfg["load"] == "closed")
        assert (cfg["rate_rps"] > 0) == (cfg["load"] in ("open", "adaptive"))
        # stored id matches the derivation, and is unique
        assert cell["id"] == cell_id(cfg)
        assert cell["id"] not in ids
        ids.add(cell["id"])
        for k in ["concurrency", "requests", "max_new"]:
            assert cfg[k] > 0
        for k in METRIC_KEYS[:-1] + TIMING_KEYS:
            v = met.get(k, tim.get(k))
            assert isinstance(v, (int, float)) and v >= 0, (cell["id"], k)
        # per_policy rows are keyed by policy identity (v3's rename)
        for row in met["per_policy"]:
            assert list(row.keys()) == POLICY_CELL_KEYS


@pytest.mark.parametrize("path", BENCH_FILES, ids=lambda p: p.name)
def test_full_matrix_coverage(path):
    """A 'full' trajectory covers every axis value of the static matrix: all
    three static speculation shapes, every cache mode, both static arrival
    modes, and >= 2 drafters (the sweep axis). The `prefix` cache column is
    closed-loop only (suite.rs CACHES), so its planes have no open-loop
    member. Adaptive cells are their own column (one per cache mode, no
    prefix) and are checked separately."""
    r = json.loads(path.read_text())
    if r["suite"] != "full":
        pytest.skip("coverage contract applies to full-suite files")
    cfgs = [c["config"] for c in r["cells"] if c["config"]["shape"] != "adaptive"]
    assert {c["shape"] for c in cfgs} == STATIC_SHAPES
    caches = {c["cache"] for c in cfgs}
    assert caches <= CACHES
    # trajectories committed before a cache column existed keep validating;
    # the CURRENT trajectory (highest PR number) must cover the whole matrix
    # as defined today
    numbered = [q for q in BENCH_FILES if q.stem.split("_")[1].isdigit()]
    current = path == max(numbered, key=lambda q: int(q.stem.split("_")[1]))
    if current:
        assert caches == CACHES
    assert {c["load"] for c in cfgs} == STATIC_LOADS
    assert len({c["drafter"] for c in cfgs}) >= 2
    # chain cells carry the chain-only AR drafter; tree/dyn cells must not
    tree_drafters = {c["drafter"] for c in cfgs if c["shape"] in ("tree", "dyn")}
    assert "target-m-ar" not in tree_drafters
    # every (shape, cache) plane appears under every load column it runs:
    # dense/paged under closed AND open, prefix under closed only
    planes = {(c["shape"], c["cache"], c["load"]) for c in cfgs}
    expect = {(s_, c_, l_) for s_ in STATIC_SHAPES for c_ in caches
              for l_ in STATIC_LOADS if not (c_ == "prefix" and l_ == "open")}
    assert planes == expect
    # the CURRENT trajectory carries the adaptive column: one cell per
    # non-prefix cache mode (the controller owns drafter + shape there)
    adaptive = [c["config"] for c in r["cells"] if c["config"]["shape"] == "adaptive"]
    if current:
        assert {c["cache"] for c in adaptive} == {"dense", "paged"}


def test_baseline_and_current_compare_cleanly():
    """The committed baseline's cell ids are a subset of the current
    trajectory's (the comparator treats a missing cell as a regression —
    CI's blocking compare should start clean)."""
    base = json.loads((REPO / "BENCH_baseline.json").read_text())
    cur = json.loads((REPO / "BENCH_10.json").read_text())
    base_ids = {c["id"] for c in base["cells"]}
    cur_ids = {c["id"] for c in cur["cells"]}
    assert base_ids <= cur_ids
