"""Paged KV cache: block-table indirection parity against the dense path.

The load-bearing property: the paged executables are *numerically invisible*
indirection — gather-through-table + the identical dense chunk forward +
scatter-back must produce bitwise-equal logits/feats to the dense `verify` /
`verify_tree` on the same logical cache state. That is what licenses the Rust
engine's dense-vs-paged byte-parity integration test (same tokens, same
acceptance lengths), and what makes `paged: true` a deployment choice rather
than a fork.

Block 0 is the reserved null block (inactive rows / unused table entries);
its garbage is never attended and only ever overwritten with more garbage.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import (
    KV_BLOCK_SIZE, S_MAX, TARGETS, kv_blocks_per_slot, num_kv_blocks,
)
from compile.masks import paged_logical_view, tree_ancestor_mask, tree_depths
from compile.model import (
    init_target, paged_gather, paged_scatter, prefill, verify, verify_paged,
    verify_tree, verify_tree_paged, zero_kv, zero_kv_paged,
)

M = kv_blocks_per_slot()  # table width per slot


@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def fresh_table(batch, rng=None, shuffle=False):
    """Disjoint per-row block tables over a fully provisioned pool, block 0
    reserved as the null block. Optionally shuffled — block ids are opaque,
    so any permutation must behave identically."""
    ids = np.arange(1, batch * M + 1)
    if shuffle:
        ids = rng.permutation(ids)
    return jnp.asarray(ids.reshape(batch, M), jnp.int32)


def pool_from_dense(cfg, dense, table):
    """Embed a dense [L,2,B,S_MAX,H,Dh] cache into a pool through `table`."""
    pool = zero_kv_paged(cfg, num_kv_blocks(dense.shape[2]), KV_BLOCK_SIZE)
    return paged_scatter(pool, table, dense)


def prefilled(cfg, params, rng, batch=1, plen=14):
    prompt = np.zeros((batch, 24), np.int32)
    prompt[:, :plen] = np.asarray(toks(rng, (batch, plen)))
    kv = zero_kv(cfg, batch)
    _, _, kv = prefill(params, cfg, jnp.asarray(prompt),
                       jnp.asarray([plen] * batch, jnp.int32), kv)
    return kv, plen


# ---------------------------------------------------------------------------
# gather / scatter mechanics
# ---------------------------------------------------------------------------

def test_gather_scatter_roundtrip(tm):
    cfg, _ = tm
    rng = np.random.default_rng(0)
    table = fresh_table(2, rng, shuffle=True)
    pool = zero_kv_paged(cfg, num_kv_blocks(2), KV_BLOCK_SIZE)
    dense = jnp.asarray(
        rng.normal(size=(cfg.n_layers, 2, 2, S_MAX, cfg.n_heads,
                         cfg.head_dim)), jnp.float32)
    pool2 = paged_scatter(pool, table, dense)
    back = paged_gather(pool2, table)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(dense))
    # the numpy reference agrees with the lowered gather
    np.testing.assert_array_equal(
        paged_logical_view(pool2, table), np.asarray(dense))


def test_scatter_only_touches_owned_blocks(tm):
    cfg, _ = tm
    rng = np.random.default_rng(1)
    # row 0 owns blocks 1..M; everything else (incl. a sentinel block M+1)
    # must be untouched by a scatter through row 0's table
    table = jnp.asarray(np.arange(1, M + 1).reshape(1, M), jnp.int32)
    pool = jnp.full(
        (cfg.n_layers, 2, num_kv_blocks(1) + 1, KV_BLOCK_SIZE, cfg.n_heads,
         cfg.head_dim), 7.25, jnp.float32)
    dense = jnp.asarray(
        rng.normal(size=(cfg.n_layers, 2, 1, S_MAX, cfg.n_heads,
                         cfg.head_dim)), jnp.float32)
    pool2 = np.asarray(paged_scatter(pool, table, dense))
    assert (pool2[:, :, 0] == 7.25).all(), "null block written by real table"
    assert (pool2[:, :, M + 1:] == 7.25).all(), "unowned blocks clobbered"


# ---------------------------------------------------------------------------
# verify parity (bitwise)
# ---------------------------------------------------------------------------

def test_verify_paged_matches_dense(tm):
    cfg, p = tm
    rng = np.random.default_rng(2)
    kv, plen = prefilled(cfg, p, rng, batch=2)
    table = fresh_table(2, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    k = 5
    chunk = toks(rng, (2, k + 1))
    clen = jnp.asarray([plen, plen], jnp.int32)

    l_ref, f_ref, kv_ref = verify(p, cfg, chunk, clen, kv)
    l_pg, f_pg, pool2 = verify_paged(p, cfg, chunk, clen, table, pool)

    np.testing.assert_array_equal(np.asarray(l_pg), np.asarray(l_ref))
    np.testing.assert_array_equal(np.asarray(f_pg), np.asarray(f_ref))
    # the written-back pool holds the same logical cache as the dense result
    # everywhere the cache is valid (committed prefix + the fresh chunk)
    view = paged_logical_view(pool2, table)
    ref = np.asarray(kv_ref)
    np.testing.assert_array_equal(view[:, :, :, :plen + k + 1],
                                  ref[:, :, :, :plen + k + 1])


def test_verify_tree_paged_matches_dense(tm):
    cfg, p = tm
    rng = np.random.default_rng(3)
    kv, plen = prefilled(cfg, p, rng)
    table = fresh_table(1, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    widths = [3, 2, 1]
    n = sum(widths)
    chunk = toks(rng, (1, n + 1))
    clen = jnp.asarray([plen], jnp.int32)
    mask = jnp.asarray(tree_ancestor_mask(widths), jnp.int32)
    depths = tuple(tree_depths(widths))

    l_ref, f_ref, kv_ref = verify_tree(p, cfg, chunk, clen, kv, mask, depths)
    l_pg, f_pg, pool2 = verify_tree_paged(p, cfg, chunk, clen, table, pool,
                                          mask, depths)

    np.testing.assert_array_equal(np.asarray(l_pg), np.asarray(l_ref))
    np.testing.assert_array_equal(np.asarray(f_pg), np.asarray(f_ref))
    view = paged_logical_view(pool2, table)
    ref = np.asarray(kv_ref)
    np.testing.assert_array_equal(view[:, :, :, :plen + n + 1],
                                  ref[:, :, :, :plen + n + 1])


def test_verify_paged_rows_are_isolated(tm):
    """Mutating row 1's chunk must not perturb row 0's logits or blocks —
    block exclusivity is what makes the pool scatter race-free."""
    cfg, p = tm
    rng = np.random.default_rng(4)
    kv, plen = prefilled(cfg, p, rng, batch=2)
    table = fresh_table(2)
    pool = pool_from_dense(cfg, kv, table)
    clen = jnp.asarray([plen, plen], jnp.int32)
    a = np.asarray(toks(rng, (2, 6)))
    b = a.copy()
    b[1] = (a[1] + 50) % 250 + 4
    la, _, pa = verify_paged(p, cfg, jnp.asarray(a), clen, table, pool)
    lb, _, pb = verify_paged(p, cfg, jnp.asarray(b), clen, table, pool)
    np.testing.assert_array_equal(np.asarray(la[0]), np.asarray(lb[0]))
    row0_blocks = np.asarray(table)[0]
    np.testing.assert_array_equal(np.asarray(pa)[:, :, row0_blocks],
                                  np.asarray(pb)[:, :, row0_blocks])
    assert not np.array_equal(np.asarray(la[1]), np.asarray(lb[1]))


def test_multistep_decode_parity(tm):
    """Thread the cache through several greedy verify steps: the dense and
    paged paths must pick identical argmax tokens at every step."""
    cfg, p = tm
    rng = np.random.default_rng(5)
    kv, plen = prefilled(cfg, p, rng)
    table = fresh_table(1, rng, shuffle=True)
    pool = pool_from_dense(cfg, kv, table)
    k = 3
    clen_v, tok_d, tok_p = plen, 5, 5
    for step in range(4):
        chunk = np.full((1, k + 1), 4 + step, np.int32)
        chunk[0, 0] = tok_d
        clen = jnp.asarray([clen_v], jnp.int32)
        ld, _, kv = verify(p, cfg, jnp.asarray(chunk), clen, kv)
        chunk[0, 0] = tok_p
        lp, _, pool = verify_paged(p, cfg, jnp.asarray(chunk), clen, table,
                                   pool)
        tok_d = int(np.argmax(np.asarray(ld)[0, 0]))
        tok_p = int(np.argmax(np.asarray(lp)[0, 0]))
        assert tok_d == tok_p, f"step {step}: {tok_d} != {tok_p}"
        clen_v += 1
