"""Algorithm 1: invariants + the headline property — within-sequence
gradient accumulation over partitioned segments reproduces the full-sequence
gradients exactly (paper §3.2 'preserving attention dependencies')."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.configs import TARGETS, DrafterConfig, TrainConfig
from compile.masks import PrecomputedMask, cod_sample, rows_from_anchors
from compile.partition import partition_rows, validate_partition
from compile.drafter import init_drafter, train_rows_forward
from compile.train import prepare_example
from compile.model import init_target, target_features

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(
    n=st.integers(4, 120),
    k=st.integers(1, 8),
    s=st.integers(1, 5),
    seed=st.integers(0, 999),
)
def test_invariants(n, k, s, seed):
    rng = np.random.default_rng(seed)
    anchors = cod_sample(n, k, 0.8, rng)
    part = partition_rows(anchors, n, k, s)
    errs = validate_partition(part, anchors, n, k)
    assert errs == [], errs[:3]


def test_paper_fig4_example():
    """The paper's n=16, K=4, r=0.7 example, including the highlighted
    violation case: position 8 at depth 2 must share a segment with its
    dependency, position 7 at depth 1."""
    anchors = [
        np.arange(16),
        np.array([0, 2, 3, 5, 6, 8, 9, 11, 13, 14]),  # depth1 positions -1
        np.array([0, 3, 5, 6, 9, 11, 13]),
        np.array([0, 3, 6, 9, 11]),
    ]
    k = 4
    part = partition_rows(anchors, 16, k, 2)
    assert validate_partition(part, anchors, 16, k) == []
    seg_of = {}
    for s, rows in enumerate(part.segment_rows):
        for r in rows:
            seg_of[r] = s
    assert seg_of[8 * k + 2] == seg_of[7 * k + 1]


def _grads_flat(g):
    return np.concatenate([np.ravel(x) for x in jax.tree_util.tree_leaves(g)])


def test_gradient_equivalence_full_vs_partitioned():
    """Summed per-segment gradients == full-pass gradients (same example,
    same sampled rows). This is the correctness claim behind within-sequence
    gradient accumulation."""
    tcfg = TARGETS["target-m"]
    tp = init_target(jax.random.PRNGKey(0), tcfg)
    dcfg = DrafterConfig(name="gtest", target="target-m", n_layers=1)
    dp = init_drafter(jax.random.PRNGKey(1), dcfg, tcfg)

    n = 48
    rng = np.random.default_rng(5)
    tokens = rng.integers(4, 250, size=n).astype(np.int32)
    feats = np.asarray(
        target_features(tp, tcfg, jnp.asarray(tokens[None]))[0][0]
    )

    def grads_for(segments, seed):
        tc = TrainConfig(seq_len=n, segments=segments, k_train=4)
        prep_rng = np.random.default_rng(seed)
        batches = prepare_example(tokens, feats, tc, PrecomputedMask(n, 4),
                                  prep_rng)
        total = None
        weight = 0.0

        def loss_sum(p, b):
            # un-normalized NLL sum so segment sums add exactly
            l, aux = train_rows_forward(p, dcfg, b)
            w = jnp.sum(b["loss_w"] * b["valid"].astype(jnp.float32))
            return l * w

        for b in batches:
            jb = {k: jnp.asarray(v) for k, v in b.items()}
            g = jax.grad(loss_sum)(dp, jb)
            w = float(np.sum(b["loss_w"] * b["valid"]))
            weight += w
            total = g if total is None else jax.tree_util.tree_map(
                jnp.add, total, g)
        return _grads_flat(total), weight

    # identical COD sampling on both sides (same prep seed)
    g_full, w_full = grads_for(1, seed=123)
    g_part, w_part = grads_for(4, seed=123)
    assert abs(w_full - w_part) < 1e-6  # same rows owned exactly once
    np.testing.assert_allclose(g_part, g_full, atol=5e-4, rtol=5e-4)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 6), seed=st.integers(0, 99))
def test_peak_cells_shrink(s, seed):
    rng = np.random.default_rng(seed)
    n, k = 256, 8
    anchors = cod_sample(n, k, 0.8, rng)
    rows_all = len(rows_from_anchors(anchors, n, k))
    part = partition_rows(anchors, n, k, s)
    peak = max(
        len(own) * (len(own) + len(extra))
        for own, extra in zip(part.segment_rows, part.segment_extra_keys)
    )
    assert peak < rows_all * rows_all, "partitioning must reduce peak cells"
