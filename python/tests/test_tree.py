"""Tree-structured speculation: topology helpers, one-pass tree verification,
and parallel tree drafting.

The two load-bearing properties:
  * chain-as-degenerate-tree — widths (1,)*K must reproduce the chain path
    (verify / draft_pe) exactly, which is what lets the Rust engine treat
    chain decoding as a topology choice;
  * path consistency — the tree-verify logits at node j must equal a plain
    chained verify over j's root path, i.e. one tree pass really does verify
    every branch as if it were decoded linearly.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import TARGETS, TREE_TOPOLOGIES, get_drafter
from compile.drafter import draft_pe, draft_pe_tree, init_drafter
from compile.masks import (
    tree_ancestor_mask,
    tree_depths,
    tree_parents,
    tree_topology_id,
)
from compile.model import init_target, prefill, verify, verify_tree, zero_kv


# ---------------------------------------------------------------------------
# topology helpers
# ---------------------------------------------------------------------------

def test_tree_parents_level_major_round_robin():
    # widths [3, 2]: nodes 1..3 at depth 1 (parent 0), nodes 4, 5 at depth 2
    # attached round-robin to nodes 1 and 2
    assert tree_parents([3, 2]) == [0, 0, 0, 1, 2]
    assert tree_parents([1, 1, 1]) == [0, 1, 2]
    assert tree_depths([3, 2]) == [0, 1, 1, 1, 2, 2]


def test_tree_parents_precede_children():
    for widths in [[1], [2, 2, 1], [3, 2, 1, 1, 1], [1, 3, 2]]:
        parents = tree_parents(widths)
        for i, p in enumerate(parents, start=1):
            assert p < i, (widths, i, p)


def test_chain_ancestor_mask_is_lower_triangular():
    m = tree_ancestor_mask([1, 1, 1, 1])
    np.testing.assert_array_equal(m, np.tril(np.ones((5, 5), bool)))


def test_ancestor_mask_matches_paths():
    widths = [2, 2, 1]
    parents = tree_parents(widths)
    m = tree_ancestor_mask(widths)
    n = len(parents) + 1
    for i in range(n):
        path, cur = set(), i
        while True:
            path.add(cur)
            if cur == 0:
                break
            cur = parents[cur - 1]
        for j in range(n):
            assert m[i, j] == (j in path), (i, j)


def test_topology_id_matches_rust_convention():
    assert tree_topology_id([1, 1, 1, 1, 1]) == "chain5"
    assert tree_topology_id([3, 2, 1, 1, 1]) == "w3x2x1x1x1"
    for topo in TREE_TOPOLOGIES:
        assert tree_topology_id(topo)  # well-formed for every registered one


# ---------------------------------------------------------------------------
# tree verification
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tm():
    cfg = TARGETS["target-m"]
    params = init_target(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks(rng, shape):
    return jnp.asarray(rng.integers(4, 250, size=shape), jnp.int32)


def prefilled(cfg, params, rng, plen=14):
    prompt = np.zeros((1, 24), np.int32)
    prompt[:, :plen] = np.asarray(toks(rng, (1, plen)))
    kv = zero_kv(cfg, 1)
    _, _, kv = prefill(params, cfg, jnp.asarray(prompt),
                       jnp.asarray([plen], jnp.int32), kv)
    return kv, plen


def test_verify_tree_chain_equals_verify(tm):
    """Degenerate chain tree: tril mask + arange depths == plain verify."""
    cfg, p = tm
    rng = np.random.default_rng(5)
    kv, plen = prefilled(cfg, p, rng)
    k = 5
    chunk = toks(rng, (1, k + 1))
    clen = jnp.asarray([plen], jnp.int32)

    l_ref, f_ref, kv_ref = verify(p, cfg, chunk, clen, kv)
    mask = jnp.asarray(tree_ancestor_mask([1] * k), jnp.int32)
    depths = tuple(tree_depths([1] * k))
    l_tree, f_tree, kv_tree = verify_tree(p, cfg, chunk, clen, kv, mask, depths)

    np.testing.assert_allclose(np.asarray(l_tree), np.asarray(l_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(f_tree), np.asarray(f_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(kv_tree), np.asarray(kv_ref),
                               atol=1e-5, rtol=1e-5)


def test_verify_tree_rows_match_linear_path_verify(tm):
    """Path consistency: node j's tree-verify logits equal a chained verify
    over [root, path tokens] at row depth(j) — for every node of a branching
    topology. This is what makes one-pass tree verification sound."""
    cfg, p = tm
    rng = np.random.default_rng(6)
    kv, plen = prefilled(cfg, p, rng)
    widths = [2, 2, 1]
    parents = tree_parents(widths)
    depths = tree_depths(widths)
    n = len(parents)
    chunk = toks(rng, (1, n + 1))
    clen = jnp.asarray([plen], jnp.int32)
    mask = jnp.asarray(tree_ancestor_mask(widths), jnp.int32)
    l_tree, _, _ = verify_tree(p, cfg, chunk, clen, kv, mask, tuple(depths))

    chunk_np = np.asarray(chunk)
    for j in range(n + 1):
        # root path of chunk slot j, root-first
        path, cur = [], j
        while cur != 0:
            path.append(cur)
            cur = parents[cur - 1]
        path = [0] + path[::-1]
        lin = jnp.asarray(chunk_np[:, path], jnp.int32)
        l_lin, _, _ = verify(p, cfg, lin, clen, kv)
        np.testing.assert_allclose(
            np.asarray(l_tree[0, j]), np.asarray(l_lin[0, len(path) - 1]),
            atol=2e-4, rtol=2e-4,
            err_msg=f"node {j} (path {path}) diverges from linear verify")


def test_verify_tree_isolates_sibling_branches(tm):
    """A node's logits must not depend on tokens in OTHER branches — mutate a
    sibling subtree and check the untouched branch's rows are unchanged."""
    cfg, p = tm
    rng = np.random.default_rng(7)
    kv, plen = prefilled(cfg, p, rng)
    widths = [2, 2]
    depths = tuple(tree_depths(widths))
    mask = jnp.asarray(tree_ancestor_mask(widths), jnp.int32)
    clen = jnp.asarray([plen], jnp.int32)
    a = np.asarray(toks(rng, (1, 5)))
    b = a.copy()
    b[0, 2] = (a[0, 2] + 50) % 250 + 4  # node 2 (the sibling branch root)
    b[0, 4] = (a[0, 4] + 50) % 250 + 4  # node 4 (child of node 2)
    la, _, _ = verify_tree(p, cfg, jnp.asarray(a), clen, kv, mask, depths)
    lb, _, _ = verify_tree(p, cfg, jnp.asarray(b), clen, kv, mask, depths)
    # branch {0, 1, 3} (root, node 1, its child node 3) is unperturbed
    for j in [0, 1, 3]:
        np.testing.assert_allclose(np.asarray(la[0, j]), np.asarray(lb[0, j]),
                                   atol=1e-5, rtol=1e-5, err_msg=f"row {j}")
    # sanity: the mutated branch did change
    assert not np.allclose(np.asarray(la[0, 2]), np.asarray(lb[0, 2]))


# ---------------------------------------------------------------------------
# tree drafting
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dm(tm):
    tcfg, _ = tm
    dcfg = get_drafter("target-m-pe4")
    params = init_drafter(jax.random.PRNGKey(3), dcfg, tcfg)
    return dcfg, tcfg, params


def draft_inputs(tcfg, rng, c=8):
    ct = toks(rng, (2, c))
    cf = jnp.asarray(rng.normal(size=(2, c, tcfg.feature_dim)), jnp.float32)
    p0 = jnp.asarray([c - 1, c + 3], jnp.int32)
    return ct, cf, p0


def test_draft_pe_tree_chain_equals_draft_pe(dm):
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(8)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    chain = draft_pe(dp, dcfg, ct, cf, p0, 5, attn_impl="jnp")
    tree = draft_pe_tree(dp, dcfg, ct, cf, p0, (1,) * 5, attn_impl="jnp")
    np.testing.assert_array_equal(np.asarray(chain), np.asarray(tree))


def test_draft_pe_tree_levels_are_depth_topk(dm):
    """Level-major output: each level's tokens are that depth's top-w chain
    candidates, rank order, distinct within the level — and rank 0 of every
    level is the chain draft."""
    dcfg, tcfg, dp = dm
    rng = np.random.default_rng(9)
    ct, cf, p0 = draft_inputs(tcfg, rng)
    widths = (3, 2, 1)
    tree = np.asarray(draft_pe_tree(dp, dcfg, ct, cf, p0, widths,
                                    attn_impl="jnp"))
    assert tree.shape == (2, sum(widths))
    chain = np.asarray(draft_pe(dp, dcfg, ct, cf, p0, len(widths),
                                attn_impl="jnp"))
    off = 0
    for d, w in enumerate(widths):
        level = tree[:, off:off + w]
        for b in range(level.shape[0]):
            assert len(set(level[b])) == w, f"depth {d+1} tokens not distinct"
            assert level[b, 0] == chain[b, d], f"rank-0 != chain at depth {d+1}"
        off += w
