"""Drafter models: P-EAGLE parallel drafting, AR chain consistency,
hidden-state variants, Pallas-vs-jnp attention agreement in the full model."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.configs import CTX_WINDOW, MASK_ID, TARGETS, DrafterConfig
from compile.drafter import (
    draft_ar,
    draft_pe,
    init_drafter,
    mtp_hidden,
    train_rows_forward,
)
from compile.model import init_target, target_features


@pytest.fixture(scope="module")
def setup():
    tcfg = TARGETS["target-m"]
    tp = init_target(jax.random.PRNGKey(0), tcfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(4, 250, size=(2, 40)), jnp.int32)
    feats, _ = target_features(tp, tcfg, toks)
    ctx_t = toks[:, -CTX_WINDOW:]
    ctx_f = feats[:, -CTX_WINDOW - 1:-1, :]
    pos0 = jnp.asarray([38, 38], jnp.int32)
    return tcfg, tp, ctx_t, ctx_f, pos0


def mk_drafter(tcfg, tp, **kw):
    cfg = DrafterConfig(name="t", target="target-m", **kw)
    params = init_drafter(jax.random.PRNGKey(1), cfg, tcfg,
                          target_embed=tp["embed"])
    return cfg, params


def test_pe_shapes_and_range(setup):
    tcfg, tp, ct, cf, p0 = setup
    for k in (3, 5, 7):
        cfg, dp = mk_drafter(tcfg, tp, n_layers=2)
        out = draft_pe(dp, cfg, ct, cf, p0, k, attn_impl="jnp")
        assert out.shape == (2, k)
        assert (np.asarray(out) >= 0).all() and (np.asarray(out) < tcfg.vocab).all()


def test_pe_pallas_equals_jnp(setup):
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=2)
    a = draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="jnp")
    b = draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="pallas")
    assert (np.asarray(a) == np.asarray(b)).all()


def test_ar_pallas_equals_jnp(setup):
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, kind="ar", n_layers=1)
    a = draft_ar(dp, cfg, ct, cf, p0, 5, attn_impl="jnp")
    b = draft_ar(dp, cfg, ct, cf, p0, 5, attn_impl="pallas")
    assert (np.asarray(a) == np.asarray(b)).all()


def test_ar_first_token_matches_pe_ntp(setup):
    """Both drafters share the NTP formulation: with identical weights, the
    FIRST draft token (pure next-token prediction from the context) must
    agree between AR and P-EAGLE."""
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=1)
    t_pe = np.asarray(draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="jnp"))[:, 0]
    cfg_ar = DrafterConfig(name="t", target="target-m", kind="ar", n_layers=1)
    t_ar = np.asarray(draft_ar(dp, cfg_ar, ct, cf, p0, 5, attn_impl="jnp"))[:, 0]
    assert (t_pe == t_ar).all()


def test_ar_chain_prefix_stability(setup):
    """AR drafting at depth K and K' > K must agree on the first K tokens
    (the chain is sequential — later steps can't change earlier ones)."""
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, kind="ar", n_layers=1)
    t3 = np.asarray(draft_ar(dp, cfg, ct, cf, p0, 3, attn_impl="jnp"))
    t7 = np.asarray(draft_ar(dp, cfg, ct, cf, p0, 7, attn_impl="jnp"))
    assert (t7[:, :3] == t3).all()


def test_pe_prefix_stability(setup):
    """P-EAGLE MTP slots attend causally, so deeper speculation must not
    change earlier draft tokens either."""
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=2)
    t3 = np.asarray(draft_pe(dp, cfg, ct, cf, p0, 3, attn_impl="jnp"))
    t7 = np.asarray(draft_pe(dp, cfg, ct, cf, p0, 7, attn_impl="jnp"))
    assert (t7[:, :3] == t3).all()


def test_hidden_variants_shapes(setup):
    tcfg, tp, ct, cf, p0 = setup
    for mode in ["shared", "depth", "ntp_depth", "ntp", "reg_ntp", "none"]:
        cfg, dp = mk_drafter(tcfg, tp, n_layers=1, hidden_mode=mode)
        out = draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="jnp")
        assert out.shape == (2, 5), mode
        h = mtp_hidden(dp, cfg, jnp.asarray([[1, 2]]),
                       jnp.zeros((1, 2, tcfg.feature_dim)))
        assert h.shape == (1, 2, cfg.d_model)


def test_mask_token_embedding_used(setup):
    """Perturbing the MASK embedding must change MTP drafts (slots 2+) but
    not the NTP draft (slot 1) — the mask token is the MTP input."""
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=2)
    base = np.asarray(draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="jnp"))
    dp2 = jax.tree_util.tree_map(lambda x: x, dp)
    dp2["embed"] = dp["embed"].at[MASK_ID].add(5.0)
    pert = np.asarray(draft_pe(dp2, cfg, ct, cf, p0, 5, attn_impl="jnp"))
    assert (base[:, 0] == pert[:, 0]).all(), "NTP must not see the mask token"
    assert (base[:, 1:] != pert[:, 1:]).any(), "MTP must depend on it"


def test_h_shared_perturbation_changes_mtp_only(setup):
    tcfg, tp, ct, cf, p0 = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=2)
    base = np.asarray(draft_pe(dp, cfg, ct, cf, p0, 5, attn_impl="jnp"))
    dp2 = jax.tree_util.tree_map(lambda x: x, dp)
    dp2["h_shared"] = dp["h_shared"] + 3.0
    pert = np.asarray(draft_pe(dp2, cfg, ct, cf, p0, 5, attn_impl="jnp"))
    assert (base[:, 0] == pert[:, 0]).all()
    assert (base[:, 1:] != pert[:, 1:]).any()


def test_train_rows_forward_smoke(setup):
    tcfg, tp, _, _, _ = setup
    cfg, dp = mk_drafter(tcfg, tp, n_layers=1)
    R = 16
    rng = np.random.default_rng(2)
    batch = {
        "tok_in": jnp.asarray(rng.integers(4, 250, (1, R)), jnp.int32),
        "depth": jnp.asarray(rng.integers(0, 4, (1, R)), jnp.int32),
        "pos": jnp.asarray(np.arange(R)[None], jnp.int32),
        "feat": jnp.asarray(rng.standard_normal((1, R, tcfg.feature_dim)), jnp.float32),
        "label": jnp.asarray(rng.integers(4, 250, (1, R)), jnp.int32),
        "loss_w": jnp.ones((1, R), jnp.float32),
        "valid": jnp.ones((1, R), bool),
        "mask": jnp.asarray(np.tril(np.ones((R, R), bool))[None]),
    }
    loss, aux = train_rows_forward(dp, cfg, batch)
    assert np.isfinite(float(loss))
    assert 0.0 <= float(aux["acc"]) <= 1.0
    g = jax.grad(lambda p: train_rows_forward(p, cfg, batch)[0])(dp)
    gn = np.sqrt(sum(float(jnp.sum(x * x)) for x in jax.tree_util.tree_leaves(g)))
    assert np.isfinite(gn) and gn > 0
