"""AOT pipeline pieces: PEW round-trip, HLO text lowering, param ordering."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.aot import to_hlo_text
from compile.configs import (
    TREE_TARGETS, drafter_modes, get_drafter, serving_drafters, tree_drafters,
)
from compile.pew import flatten_named, read_pew, unflatten_named, write_pew


def test_drafter_capability_modes():
    """The per-drafter capability record the manifest carries: AR scans are
    chain-only (no single-pass tree draft); parallel drafters support every
    speculation mode the engine serves."""
    assert drafter_modes(get_drafter("target-m-ar")) == ["chain"]
    assert drafter_modes(get_drafter("target-m-pe4")) == ["chain", "tree", "dyn"]
    assert drafter_modes(get_drafter("target-m-pe2")) == ["chain", "tree", "dyn"]
    for d in serving_drafters():
        assert "chain" in drafter_modes(d), d.name


def test_tree_drafters_cover_all_tree_capable_serving_drafters():
    """Tree/dyn executables are lowered for EVERY tree-capable serving
    drafter of the tree targets (multi-drafter serving needs more than the
    old single pe4 entry), and never for the chain-only AR scan."""
    td = tree_drafters()
    assert "target-m-pe4" in td
    assert "target-m-pe2" in td
    assert "target-m-ar" not in td
    for name in td:
        d = get_drafter(name)
        assert d.target in TREE_TARGETS
        assert "tree" in drafter_modes(d)


def test_pew_roundtrip(tmp_path):
    tensors = [
        ("blocks.0.wq", np.arange(12, dtype=np.float32).reshape(3, 4)),
        ("embed", np.ones((5, 2), np.float32) * 0.5),
        ("ids", np.asarray([1, 2, 3], np.int32)),
    ]
    p = tmp_path / "t.pew"
    write_pew(p, tensors)
    back = read_pew(p)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        np.testing.assert_array_equal(a, b)


def test_flatten_order_matches_jit_argument_order():
    """The manifest's param_order must be exactly the order jax.jit flattens
    the params pytree — otherwise the Rust runtime feeds weights to the
    wrong executable arguments."""
    params = {
        "embed": jnp.ones((4, 2)),
        "blocks": [{"wq": jnp.ones((2, 2)), "ln": jnp.ones((2,))}],
        "lm_head": jnp.ones((2, 4)),
    }
    named, _ = flatten_named(params)
    flat, _ = jax.tree_util.tree_flatten(params)
    assert len(named) == len(flat)
    for (name, a), b in zip(named, flat):
        assert a.shape == np.asarray(b).shape, name

    rebuilt = unflatten_named(named, params)
    for x, y in zip(jax.tree_util.tree_leaves(rebuilt),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_hlo_text_lowering_multi_output():
    def f(x, y):
        return x @ y, x + 1.0

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(f).lower(s, s))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text
    # untupled entry layout (return_tuple=False) — two results
    assert "->(f32[2,2]{1,0}, f32[2,2]{1,0})" in text.replace(" ,", ",")


def test_hlo_text_with_pallas_kernel():
    from compile.kernels.draft_attention import draft_attention

    def f(q, k, v, b):
        return draft_attention(q, k, v, b)

    q = jax.ShapeDtypeStruct((1, 2, 8, 8), jnp.float32)
    b = jax.ShapeDtypeStruct((1, 1, 8, 8), jnp.float32)
    text = to_hlo_text(jax.jit(f).lower(q, q, q, b))
    assert text.startswith("HloModule")
    # interpret-mode pallas must lower to plain HLO (no mosaic custom-call)
    assert "custom-call" not in text or "mosaic" not in text.lower()


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "..", "..",
                                    "artifacts", "manifest.json")),
    reason="artifacts not built",
)
def test_manifest_consistent_with_weights():
    import json

    root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                        "artifacts"))
    with open(os.path.join(root, "manifest.json")) as f:
        m = json.load(f)
    assert m["vocab"] == 256
    for name, t in m["targets"].items():
        tensors = read_pew(os.path.join(root, t["weights"]))
        assert [n for n, _ in tensors] == t["param_order"], name
    # every executable file exists
    for e in m["executables"]:
        assert os.path.exists(os.path.join(root, e["path"])), e["name"]
