"""§3.1 mask machinery: position-invariance (Fig 3), PARD equivalence, COD."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.masks import (
    PrecomputedMask,
    attend_allowed,
    cod_sample,
    expected_total_rows,
    full_mask_dense,
    pard_mask,
    rows_from_anchors,
)

SETTINGS = dict(max_examples=30, deadline=None)


def test_depth0_is_causal():
    m = full_mask_dense(8, 1)
    assert (m == np.tril(np.ones((8, 8), bool))).all()


@settings(**SETTINGS)
@given(n=st.integers(2, 24), k=st.integers(1, 8))
def test_dense_matches_scalar_predicate(n, k):
    m = full_mask_dense(n, k)
    ids = np.arange(n * k)
    for r in ids[:: max(1, len(ids) // 40)]:
        for c in ids[:: max(1, len(ids) // 40)]:
            assert m[r, c] == attend_allowed(r // k, r % k, c // k, c % k)


@settings(**SETTINGS)
@given(n_long=st.integers(2, 40), k=st.integers(1, 8), data=st.data())
def test_fig3_position_invariance(n_long, k, data):
    """Paper Fig 3: shorter mask == top-left submatrix of a longer mask."""
    n_short = data.draw(st.integers(1, n_long))
    long = PrecomputedMask(n_long, k)
    short = full_mask_dense(n_short, k)
    view = long.slice_view(n_short)
    assert view.shape == short.shape
    assert (view == short).all()


def test_slice_view_is_view_not_copy():
    pm = PrecomputedMask(32, 4)
    v = pm.slice_view(8)
    assert v.base is pm.mask  # numpy view — O(1), no allocation


@settings(**SETTINGS)
@given(n=st.integers(2, 24), k=st.integers(1, 6), seed=st.integers(0, 999))
def test_pard_equals_amortized_gather(n, k, seed):
    rng = np.random.default_rng(seed)
    anchors = cod_sample(n, k, 0.8, rng)
    rows = rows_from_anchors(anchors, n, k)
    if len(rows) == 0:
        return
    pm = PrecomputedMask(n, k)
    np.testing.assert_array_equal(pm.gather(rows), pard_mask(rows, k))


@settings(**SETTINGS)
@given(n=st.integers(4, 100), k=st.integers(1, 8), seed=st.integers(0, 999))
def test_cod_nested_and_counted(n, k, seed):
    rng = np.random.default_rng(seed)
    r = 0.8
    anchors = cod_sample(n, k, r, rng)
    assert (anchors[0] == np.arange(n)).all()
    for d in range(1, k):
        want = min(int(round(n * r ** d)), len(anchors[d - 1]))
        assert len(anchors[d]) == want
        assert set(anchors[d]) <= set(anchors[d - 1])  # nested (Alg 1 needs this)


def test_chain_parents_always_sampled():
    # nested anchors => every row (p,d) has its chain parent (p-1,d-1)
    rng = np.random.default_rng(7)
    n, k = 64, 8
    anchors = cod_sample(n, k, 0.8, rng)
    rowset = set(rows_from_anchors(anchors, n, k).tolist())
    for rid in rowset:
        p, d = rid // k, rid % k
        if d >= 1 and p - 1 <= n - 2:
            parent = (p - 1) * k + (d - 1)
            assert parent in rowset, f"({p},{d}) missing parent"


def test_expected_rows_formula():
    # paper §3.2 example: 8192 tokens, K=8, r=0.8 -> ~34K positions
    assert abs(expected_total_rows(8192, 8, 0.8) - 34e3) < 1.5e3
