"""L1 correctness: the Pallas draft-attention kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/mask structures; assert_allclose against
kernels/ref.py — the CORE correctness signal for the compiled hot path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels.draft_attention import (
    draft_attention,
    draft_attention_flash,
    mxu_utilization_estimate,
    vmem_estimate_bytes,
)
from compile.kernels.ref import ref_attention, ref_attention_varlen

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def causal_bias(t, s):
    m = np.tril(np.ones((t, s), bool), k=s - t)
    return jnp.asarray(np.where(m, 0.0, -1e9), jnp.float32)[None, None]


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(1, 18),
    s=st.integers(1, 24),
    dh=st.sampled_from([4, 8, 12, 16]),
    seed=st.integers(0, 2**16),
)
def test_single_block_matches_ref(b, h, t, s, dh, seed):
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, h, t, dh), jnp.float32)
    k = rand(rng, (b, h, s, dh), jnp.float32)
    v = rand(rng, (b, h, s, dh), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((b, 1, t, s)), jnp.float32)
    got = draft_attention(q, k, v, bias)
    want = ref_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 2),
    h=st.integers(1, 3),
    tq=st.sampled_from([4, 8]),
    nt=st.integers(1, 3),
    ns=st.integers(1, 3),
    dh=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**16),
)
def test_flash_matches_ref(b, h, tq, nt, ns, dh, seed):
    ts = 32
    t, s = tq * nt, ts * ns
    rng = np.random.default_rng(seed)
    q = rand(rng, (b, h, t, dh), jnp.float32)
    k = rand(rng, (b, h, s, dh), jnp.float32)
    v = rand(rng, (b, h, s, dh), jnp.float32)
    bias = jnp.asarray(rng.standard_normal((1, 1, t, s)), jnp.float32)
    got = draft_attention_flash(q, k, v, bias, tq=tq, ts=ts)
    want = ref_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-5, rtol=3e-5)


def test_causal_masked_agrees():
    rng = np.random.default_rng(0)
    b, h, t, s, dh = 2, 4, 15, 15, 16
    q = rand(rng, (b, h, t, dh), jnp.float32)
    k = rand(rng, (b, h, s, dh), jnp.float32)
    v = rand(rng, (b, h, s, dh), jnp.float32)
    bias = causal_bias(t, s)
    got = draft_attention(q, k, v, bias)
    want = ref_attention(q, k, v, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_fully_masked_rows_are_finite():
    # a row with all keys masked must not produce NaNs (uniform fallback)
    rng = np.random.default_rng(1)
    b, h, t, s, dh = 1, 1, 4, 6, 8
    q = rand(rng, (b, h, t, dh), jnp.float32)
    k = rand(rng, (b, h, s, dh), jnp.float32)
    v = rand(rng, (b, h, s, dh), jnp.float32)
    bias = jnp.full((1, 1, t, s), -1e9, jnp.float32)
    got = np.asarray(draft_attention(q, k, v, bias))
    assert np.isfinite(got).all()


def test_varlen_ref_masks_tail():
    rng = np.random.default_rng(2)
    b, h, t, s, dh = 2, 2, 3, 10, 8
    q = rand(rng, (b, h, t, dh), jnp.float32)
    k = rand(rng, (b, h, s, dh), jnp.float32)
    v = rand(rng, (b, h, s, dh), jnp.float32)
    bias = jnp.zeros((b, 1, t, s), jnp.float32)
    kv_len = jnp.asarray([4, 10], jnp.int32)
    out = ref_attention_varlen(q, k, v, bias, kv_len)
    # batch 0 must ignore keys >= 4: perturbing them changes nothing
    k2 = k.at[0, :, 4:, :].set(99.0)
    v2 = v.at[0, :, 4:, :].set(-99.0)
    out2 = ref_attention_varlen(q, k2, v2, bias, kv_len)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(out2[0]), atol=1e-5)
    assert not np.allclose(np.asarray(out[1]), np.asarray(ref_attention_varlen(q, k2, v2, bias, jnp.asarray([4, 4]))[1]))


def test_kernel_inside_jit_lowerable():
    # the kernel must lower inside jit (the AOT path) without python leaks
    b, h, t, dh = 1, 2, 10, 8

    @jax.jit
    def f(q, k, v, bias):
        return draft_attention(q, k, v, bias)

    rng = np.random.default_rng(3)
    q = rand(rng, (b, h, t, dh), jnp.float32)
    bias = jnp.zeros((1, 1, t, t), jnp.float32)
    out = f(q, q, q, bias)
    assert out.shape == (b, h, t, dh)


def test_vmem_estimate_within_budget():
    # serving shapes must fit a 16 MiB VMEM budget with the default tiles
    assert vmem_estimate_bytes(8, 128, 64) < 16 * 1024 * 1024


def test_mxu_utilization_estimates():
    assert mxu_utilization_estimate(15, 15, 16) <= 1.0
    # perfectly-aligned shapes hit 1.0
    assert mxu_utilization_estimate(8, 128, 128) == 1.0
