"""Minimal Adam + linear-warmup/decay schedule (hand-rolled; offline env)."""

import jax
import jax.numpy as jnp


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    c1 = 1.0 - jnp.power(0.9, tf)
    c2 = 1.0 - jnp.power(0.999, tf)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ / c1) / (jnp.sqrt(v_ / c2) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}


def linear_schedule(step, total, peak, warmup):
    """Linear warmup to `peak` over `warmup` steps, then linear decay to 0
    (the paper's schedule, warmup_ratio 0.0025)."""
    s = jnp.asarray(step, jnp.float32)
    w = jnp.maximum(jnp.asarray(warmup, jnp.float32), 1.0)
    up = s / w
    down = jnp.maximum(0.0, (total - s) / jnp.maximum(total - w, 1.0))
    return peak * jnp.minimum(up, down)
