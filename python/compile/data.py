"""Seeded synthetic corpora standing in for the paper's datasets.

The paper trains on UltraChat / GSM-8K / OpenCodeInstruct and evaluates on
HumanEval / MT-Bench / GSM-8K(test). We substitute three seeded *phrase-bank*
regimes (DESIGN.md §Hardware-Adaptation): a regime owns a bank of fixed token
phrases (deterministic spans, like code idioms / formulaic math steps) chained
by a temperature-controlled first-order process at phrase boundaries. Within a
phrase the next token is deterministic (highly predictable — what a drafter
exploits); at boundaries entropy is regime-controlled:

  * humaneval ("code")  — long phrases, cold boundaries (paper: highest AL)
  * gsm8k     ("math")  — mid phrases, mid boundaries
  * mtbench   ("chat")  — short phrases, hot boundaries (paper: lowest AL)

Every phrase starts with an anchor token unique to it, so a 1-2 token context
identifies the phrase + offset — learnable by the mini target and mirrored
bit-for-bit in rust/src/workload/corpus.rs from the exported tables.
"""

import numpy as np

from .configs import VOCAB, BOS_ID, EOS_ID, FIRST_CONTENT_ID

# regime -> (seed, n_phrases, min_len, max_len, branch, temperature)
REGIMES = {
    "humaneval": (101, 48, 5, 9, 3, 0.30),
    "gsm8k": (202, 48, 4, 7, 3, 0.55),
    "mtbench": (303, 48, 3, 5, 4, 1.00),
}

N_PHRASES = 48
BODY_LO = FIRST_CONTENT_ID + N_PHRASES           # body tokens share a pool
BODY_HI = VOCAB


class PhraseRegime:
    """Phrase-bank source: deterministic phrase bodies + stochastic chaining."""

    def __init__(self, name):
        seed, n, lo, hi, branch, temp = REGIMES[name]
        self.name = name
        self.n = n
        self.branch = branch
        rng = np.random.default_rng(seed)
        self.phrases = []
        for i in range(n):
            length = int(rng.integers(lo, hi + 1))
            body = rng.integers(BODY_LO, BODY_HI, size=length - 1)
            anchor = FIRST_CONTENT_ID + i        # unique phrase anchor token
            self.phrases.append(np.concatenate([[anchor], body]).astype(np.int32))
        # first-order phrase transitions: each phrase chains to `branch`
        # successors with a temperature-peaked categorical
        self.succ = rng.integers(0, n, size=(n, branch)).astype(np.int32)
        logits = rng.normal(size=(n, branch)) / temp
        e = np.exp(logits - logits.max(axis=1, keepdims=True))
        self.probs = (e / e.sum(axis=1, keepdims=True)).astype(np.float32)

    def sample_seq(self, length, rng):
        """Sample [BOS, tokens...] of exactly `length` tokens (no EOS)."""
        out = np.empty(length, dtype=np.int32)
        out[0] = BOS_ID
        i = 1
        pid = int(rng.integers(self.n))
        while i < length:
            ph = self.phrases[pid]
            take = min(len(ph), length - i)
            out[i:i + take] = ph[:take]
            i += take
            pid = int(self.succ[pid, rng.choice(self.branch, p=self.probs[pid])])
        return out

    def sample_batch(self, batch, length, rng):
        return np.stack([self.sample_seq(length, rng) for _ in range(batch)])

    def export_tables(self):
        """Serializable regime tables for the Rust mirror."""
        return {
            "name": self.name,
            "phrases": [p.tolist() for p in self.phrases],
            "succ": self.succ.tolist(),
            "probs": [[float(x) for x in row] for row in self.probs],
        }


# Backwards-friendly alias used throughout train/pretrain
MarkovRegime = PhraseRegime


def training_batch(regimes, batch, length, rng):
    """Mixture batch across regimes (the paper trains on all three datasets)."""
    names = list(regimes)
    out = np.empty((batch, length), dtype=np.int32)
    for i in range(batch):
        r = regimes[names[rng.integers(len(names))]]
        out[i] = r.sample_seq(length, rng)
    return out


def eval_prompts(regime, count, prompt_len, seed):
    """Held-out prompt set for a regime (disjoint seed stream from training)."""
    rng = np.random.default_rng(seed * 7919 + 17)
    r = PhraseRegime(regime)
    return r.sample_batch(count, prompt_len, rng)


# ---------------------------------------------------------------------------
# Figure 1: sequence-length (prompt + generation) distribution
# ---------------------------------------------------------------------------

# Lognormal mixture fit to the paper's reported quantiles (median 3891,
# P90 10800, P99 20000) then scaled by LEN_SCALE for the mini testbed.
LEN_SCALE = 1.0 / 32.0
_LOGN_MODES = [
    # (weight, mu, sigma) over paper-scale token counts, fit to the paper's
    # median 3891 / P90 10800 / P99 20000
    (0.80, 8.10, 0.60),   # main reasoning mass (~median 3.3K)
    (0.20, 9.20, 0.40),   # long-tail reasoning traces
]


def sample_paper_length(rng):
    w = rng.random()
    acc = 0.0
    for weight, mu, sigma in _LOGN_MODES:
        acc += weight
        if w <= acc:
            return float(np.exp(rng.normal(mu, sigma)))
    weight, mu, sigma = _LOGN_MODES[-1]
    return float(np.exp(rng.normal(mu, sigma)))


def length_distribution_stats(samples):
    s = np.sort(np.asarray(samples))
    q = lambda p: float(s[min(len(s) - 1, int(p * len(s)))])
    return {"median": q(0.50), "p90": q(0.90), "p99": q(0.99)}
