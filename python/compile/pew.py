"""PEW — the P-EAGLE weight interchange format (Python writer/reader).

Binary layout (little-endian), mirrored by rust/src/runtime/weights.rs:

    magic   b"PEW1"
    u32     tensor count
    repeat:
      u16   name length, then name bytes (utf-8)
      u8    dtype (0 = f32, 1 = i32)
      u8    ndim
      u32*  dims
      raw   data (dtype * prod(dims))

Weights ride next to the HLO text artifacts because the executables take
parameters as runtime arguments (uploaded once as device-resident PJRT
buffers) instead of baked-in constants — keeps HLO text small and lets many
executables share one weight file.
"""

import struct

import numpy as np

MAGIC = b"PEW1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write_pew(path, tensors):
    """tensors: list of (name, np.ndarray) in a deterministic order."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_pew(path):
    """Returns list of (name, np.ndarray) preserving write order."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, f"{path}: bad magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            dtype = np.dtype(DTYPES_INV[dt])
            n = int(np.prod(dims)) if dims else 1
            arr = np.frombuffer(f.read(n * dtype.itemsize), dtype=dtype)
            out.append((name, arr.reshape(dims)))
    return out


# ---------------------------------------------------------------------------
# pytree <-> named flat list (deterministic parameter ordering)
# ---------------------------------------------------------------------------

def flatten_named(params):
    """Flatten a params pytree into [(path_name, array)] using jax's
    canonical flatten order — the SAME order jit uses for lowered arguments."""
    import jax

    flat, treedef = jax.tree_util.tree_flatten(params)
    paths = jax.tree_util.tree_flatten_with_path(params)[0]

    def fmt(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        return ".".join(parts)

    names = [fmt(p) for p, _ in paths]
    return list(zip(names, [np.asarray(x) for x in flat])), treedef


def unflatten_named(tensors, template):
    """Rebuild a params pytree shaped like `template` from (name, arr) pairs
    (order must match flatten_named(template))."""
    import jax

    flat, treedef = jax.tree_util.tree_flatten(template)
    assert len(flat) == len(tensors), (len(flat), len(tensors))
    import jax.numpy as jnp

    return jax.tree_util.tree_unflatten(
        treedef, [jnp.asarray(a) for _, a in tensors])
