"""Pretrain the mini target models on the synthetic corpus mixture.

The paper's targets are frozen production models; ours must first *become*
predictable language models so acceptance length is a meaningful signal
(DESIGN.md §Hardware-Adaptation). One Adam run per target over the three
regime mixture, a few hundred steps — enough to drive greedy continuations
close to the Markov source's argmax structure.
"""

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .configs import TargetConfig
from .model import init_target, target_loss
from .optim import adam_init, adam_update, linear_schedule


def pretrain_target(cfg: TargetConfig, steps=500, batch=32, seq_len=128,
                    lr=3e-3, seed=0, log_every=100, verbose=True):
    key = jax.random.PRNGKey(seed + hash(cfg.name) % 1000)
    params = init_target(key, cfg)
    opt = adam_init(params)
    regimes = {n: data_mod.MarkovRegime(n) for n in data_mod.REGIMES}
    rng = np.random.default_rng(seed + 77)

    @jax.jit
    def step_fn(params, opt, tokens, lr_now):
        loss, grads = jax.value_and_grad(target_loss)(params, cfg, tokens)
        params, opt = adam_update(params, grads, opt, lr_now)
        return params, opt, loss

    history = []
    t0 = time.time()
    for s in range(steps):
        tokens = jnp.asarray(
            data_mod.training_batch(regimes, batch, seq_len, rng), jnp.int32)
        lr_now = linear_schedule(s, steps, lr, max(1, int(steps * 0.02)))
        params, opt, loss = step_fn(params, opt, tokens, lr_now)
        if s % log_every == 0 or s == steps - 1:
            history.append({"step": s, "loss": float(loss)})
            if verbose:
                print(f"  [{cfg.name}] step {s:4d} loss {float(loss):.4f} "
                      f"({time.time()-t0:.1f}s)")
    return params, history
