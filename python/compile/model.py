"""L2 target model: LLaMA-style decoder-only transformer with EAGLE-3 feature
taps and explicit-KV serving entry points (prefill / verify).

The serving executables are pure functions over (params, state) so aot.py can
lower them to HLO text with weights passed as runtime arguments — the Rust
runtime uploads weights once as device-resident PJRT buffers and threads the
KV cache through successive `verify` calls without host round-trips.

KV cache layout: [L, 2, B, S_MAX, H, Dh] float32 (k then v per layer).
`cache_len[b]` counts valid positions; every attended position is either
< cache_len or freshly written by the current call (see DESIGN.md for the
overwrite-safety argument).
"""

from functools import partial

import jax
import jax.numpy as jnp

from .common import (
    NEG_INF,
    apply_rope,
    cross_entropy,
    dense_init,
    embed_init,
    init_block,
    mask_to_bias,
    rms_norm,
    run_block,
    sdpa,
    swiglu,
)
from .configs import S_MAX, TargetConfig


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_target(key, cfg: TargetConfig):
    keys = jax.random.split(key, cfg.n_layers + 3)
    params = {
        "embed": embed_init(keys[0], cfg.vocab, cfg.d_model),
        "blocks": [
            init_block(keys[i + 1], cfg.d_model, cfg.n_heads, cfg.ffn_dim)
            for i in range(cfg.n_layers)
        ],
        "ln_f": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": dense_init(keys[-1], cfg.d_model, cfg.vocab),
    }
    return params


# ---------------------------------------------------------------------------
# Training forward (pretraining the target on the synthetic corpus)
# ---------------------------------------------------------------------------

def target_forward_train(params, cfg: TargetConfig, tokens):
    """tokens: [B, S] -> logits [B, S, V]. Plain causal LM forward."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    causal = jnp.tril(jnp.ones((S, S), bool))
    bias = mask_to_bias(causal)[None, None]
    for blk in params["blocks"]:
        x = run_block(x, blk, positions, bias, cfg.n_heads, cfg.rope_theta,
                      cfg.norm_eps)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x @ params["lm_head"]


def target_loss(params, cfg: TargetConfig, tokens):
    logits = target_forward_train(params, cfg, tokens)
    labels = tokens[:, 1:]
    return cross_entropy(logits[:, :-1], labels)


# ---------------------------------------------------------------------------
# Shared serving forward over a token chunk with explicit KV cache
# ---------------------------------------------------------------------------

def _chunk_forward(params, cfg: TargetConfig, tokens, start, kv, key_limit,
                   pos_offsets=None, chunk_mask=None):
    """Run a [B, T] token chunk at per-batch offset `start` against the cache.

    tokens: [B, T] int32; start: [B] int32 (chunk position offsets);
    kv: [L, 2, B, S_MAX, H, Dh]; key_limit: [B, T] int32 — position i may
    attend cache keys at q < key_limit[b, i] (chunk keys are scattered into
    the cache *before* attention, so chunk-causal structure is expressed
    through key_limit too).

    Tree chunks break both linearities: `pos_offsets` ([T] int32) replaces
    the implicit arange for RoPE (slot j's position is start + depth(j), not
    start + j), and `chunk_mask` (bool [T, T]) ORs chunk-internal
    attendability on top of key_limit (slot i may attend the cache slot
    holding chunk slot j iff chunk_mask[i, j] — the cross-node ancestor
    mask). Chain verification is the degenerate case pos_offsets=arange,
    chunk_mask=tril (expressed through key_limit instead).

    Dynamic-tree chunks break them PER BATCH ROW: `pos_offsets` may be
    [B, T] and `chunk_mask` [B, T, T] (each slot activates its own
    confidence-selected node subset — see verify_tree_dyn). Static inputs
    take the shared fast path unchanged.

    Returns (features [B,T,3d], logits [B,T,V], new_kv).
    """
    L, H, Dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    B, T = tokens.shape
    x = params["embed"][tokens]
    offs = (jnp.arange(T, dtype=jnp.int32) if pos_offsets is None
            else jnp.asarray(pos_offsets, jnp.int32))
    positions = start[:, None] + (offs if offs.ndim == 2 else offs[None, :])

    key_pos = jnp.arange(S_MAX, dtype=jnp.int32)
    # [B, T, S_MAX] -> [B, 1, T, S_MAX]
    allow = key_pos[None, None, :] < key_limit[:, :, None]
    if chunk_mask is not None:
        # cache slot q holds chunk slot q - start[b] (the verify scatter
        # below writes chunk slot j at start + j)
        q_rel = key_pos[None, :] - start[:, None]              # [B, S_MAX]
        in_chunk = (q_rel >= 0) & (q_rel < T)
        q_clip = jnp.clip(q_rel, 0, T - 1)
        if chunk_mask.ndim == 3:
            # per-batch mask: gather each row's own columns
            gathered = jnp.take_along_axis(
                chunk_mask, jnp.broadcast_to(q_clip[:, None, :], (B, T, S_MAX)),
                axis=2)                                        # [B, T, S_MAX]
            allow = allow | (gathered & in_chunk[:, None, :])
        else:
            gathered = chunk_mask[:, q_clip]                   # [T, B, S_MAX]
            allow = allow | (jnp.transpose(gathered, (1, 0, 2)) & in_chunk[:, None, :])
    bias = mask_to_bias(allow)[:, None]

    taps = {i: None for i in cfg.feature_layers}
    new_kv = []
    for li, blk in enumerate(params["blocks"]):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q = (h @ blk["wq"]).reshape(B, T, H, Dh)
        k = (h @ blk["wk"]).reshape(B, T, H, Dh)
        v = (h @ blk["wv"]).reshape(B, T, H, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

        # scatter this chunk's K/V into the cache at per-batch offsets
        def scatter(cache_bshd, new_bthd, off_b):
            return jax.vmap(
                lambda c, n, o: jax.lax.dynamic_update_slice(c, n, (o, 0, 0))
            )(cache_bshd, new_bthd, off_b)

        k_cache = scatter(kv[li, 0], k, start)
        v_cache = scatter(kv[li, 1], v, start)
        new_kv.append(jnp.stack([k_cache, v_cache]))

        a = sdpa(
            q.transpose(0, 2, 1, 3),
            k_cache.transpose(0, 2, 1, 3),
            v_cache.transpose(0, 2, 1, 3),
            bias,
        )
        a = a.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        x = x + a @ blk["wo"]
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, blk["w_gate"], blk["w_up"], blk["w_down"])
        if li in taps:
            taps[li] = x

    feats = jnp.concatenate([taps[i] for i in cfg.feature_layers], axis=-1)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return feats, logits, jnp.stack(new_kv)


# ---------------------------------------------------------------------------
# Serving entry points (lowered by aot.py)
# ---------------------------------------------------------------------------

def prefill(params, cfg: TargetConfig, tokens, prompt_len, kv):
    """Prefill a padded prompt.

    tokens: [B, P] (positions >= prompt_len[b] are PAD garbage);
    prompt_len: [B] int32; kv: zeroed cache.

    Returns (last_logits [B, V], feats [B, P, 3d], new_kv). Garbage rows
    beyond prompt_len produce garbage feats/KV that are never attended
    (overwrite-safety argument in DESIGN.md).
    """
    B, P = tokens.shape
    start = jnp.zeros((B,), jnp.int32)
    # position i attends cache keys < i+1 (self-causal); padding rows simply
    # attend the real prefix — their outputs are discarded.
    key_limit = jnp.broadcast_to(
        jnp.arange(1, P + 1, dtype=jnp.int32)[None, :], (B, P)
    )
    feats, logits, new_kv = _chunk_forward(params, cfg, tokens, start, kv, key_limit)
    last = prompt_len - 1
    last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return last_logits, feats, new_kv


def prefill_cached(params, cfg: TargetConfig, tokens, prompt_len, start, kv):
    """Tail-only prefill behind a cached prompt prefix (prefix-cache hit).

    tokens: [B, W] — the prompt TAIL, left-aligned: tokens[b, i] holds
    prompt position start[b] + i (slots at or past prompt_len[b] - start[b]
    are PAD garbage); prompt_len: [B] int32 (the FULL prompt length);
    start: [B] int32 — positions [0, start[b]) of `kv` already hold the
    prefix KV (gathered from shared pool blocks by the engine);
    kv: [L, 2, B, S_MAX, H, Dh].

    Returns (last_logits [B, V], feats [B, W, 3d], new_kv); feats[b, i] is
    the feature row for prompt position start[b] + i. Masked attention keys
    at or beyond key_limit contribute exactly-zero weight, and softmax rows
    reduce independently in the same order as a full prefill's, so the tail
    rows here are BITWISE equal to the same rows of `prefill` over the whole
    prompt — pinned by tests/test_prefix_cache.py; with start == 0 this IS
    `prefill` modulo the token operand width.
    """
    B, W = tokens.shape
    # tail slot i sits at logical position start + i and attends cache keys
    # q < start + i + 1: the whole cached prefix plus its own self-causal
    # chunk prefix (chunk keys are scattered into the cache before attention)
    key_limit = start[:, None] + jnp.arange(1, W + 1, dtype=jnp.int32)[None, :]
    feats, logits, new_kv = _chunk_forward(params, cfg, tokens, start, kv,
                                           key_limit)
    last = prompt_len - 1 - start
    last_logits = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return last_logits, feats, new_kv


def verify(params, cfg: TargetConfig, chunk, cache_len, kv):
    """Verify a speculation chunk [bonus_token, d_1 .. d_K].

    chunk: [B, K+1] int32; cache_len: [B] int32 (valid cache positions; the
    chunk is written at cache_len .. cache_len+K); kv: running cache.

    Returns (logits [B, K+1, V], feats [B, K+1, 3d], new_kv). logits[:, i]
    is the target distribution for position cache_len+i+1 — i.e. the
    verification signal for draft token d_{i+1} and the bonus sample.
    """
    B, T = chunk.shape
    start = cache_len
    key_limit = (cache_len[:, None]
                 + jnp.arange(1, T + 1, dtype=jnp.int32)[None, :])
    feats, logits, new_kv = _chunk_forward(params, cfg, chunk, start, kv, key_limit)
    return logits, feats, new_kv


def verify_tree(params, cfg: TargetConfig, chunk, cache_len, kv, tree_mask,
                depths):
    """One-pass tree verification of a chunk [root, node_1 .. node_N].

    chunk: [B, N+1] int32 in chunk-slot order (slot 0 = last committed
    token, slots 1..N the draft-tree nodes, level-major); cache_len: [B]
    int32; tree_mask: [N+1, N+1] int32 runtime input — the cross-node
    ancestor mask (1 = slot i may attend slot j), built once per topology by
    `masks.tree_ancestor_mask` (Python) / `masking::tree` (Rust);
    depths: STATIC per-slot depth offsets (`masks.tree_depths(widths)`),
    baked into the lowered HLO — slot j's RoPE position is
    cache_len + depths[j], so an accepted path's entries stay RoPE-valid
    after the engine compacts them to contiguous cache positions.

    Every chunk slot also attends all committed cache positions
    (q < cache_len). Returns (logits [B,N+1,V], feats [B,N+1,3d], new_kv);
    logits[:, j] is the target distribution for the token AFTER chunk slot j
    — the verification signal for slot j's children and the bonus sample.

    With depths = arange(N+1) and a lower-triangular mask this reproduces
    `verify` exactly (chain = degenerate tree; see tests/test_tree.py).
    """
    B, T = chunk.shape
    key_limit = jnp.broadcast_to(cache_len[:, None], (B, T))
    feats, logits, new_kv = _chunk_forward(
        params, cfg, chunk, cache_len, kv, key_limit,
        pos_offsets=depths, chunk_mask=tree_mask != 0)
    return logits, feats, new_kv


def verify_tree_dyn(params, cfg: TargetConfig, chunk, cache_len, kv, tree_mask,
                    depth_offsets):
    """Dynamic-tree verification over a max-shape envelope.

    Like `verify_tree`, but lowered ONCE per envelope with the topology as
    per-batch RUNTIME inputs: tree_mask [B, N+1, N+1] int32 (each row's
    compacted subset mask — masks.tree_subset_mask / masking/dynamic.rs;
    inactive tail rows/cols all-zero, so tail slots attend only the
    committed cache and are attended by nobody) and depth_offsets
    [B, N+1] int32 (each compacted slot's envelope depth, 0-padded). The
    chunk carries [root, selected nodes.., PAD..] in compacted layout.

    With every node selected this reproduces `verify_tree` bitwise — the
    degenerate case that licenses dynamic mode (tests/test_tree_dyn.py) —
    and each active slot's logits still equal a linear verify over its root
    path (path consistency holds per subset).
    """
    B, T = chunk.shape
    key_limit = jnp.broadcast_to(cache_len[:, None], (B, T))
    feats, logits, new_kv = _chunk_forward(
        params, cfg, chunk, cache_len, kv, key_limit,
        pos_offsets=depth_offsets, chunk_mask=tree_mask != 0)
    return logits, feats, new_kv


def zero_kv(cfg: TargetConfig, batch):
    return jnp.zeros(
        (cfg.n_layers, 2, batch, S_MAX, cfg.n_heads, cfg.head_dim), jnp.float32
    )


# ---------------------------------------------------------------------------
# Paged-KV serving entry points (block-table indirection; lowered by aot.py)
# ---------------------------------------------------------------------------
#
# The paged physical cache is a block pool [L, 2, NB, BS, H, Dh]; a slot's
# logical position q lives in pool block block_table[b, q // BS] at offset
# q % BS. The paged executables are exact functional twins of the dense ones:
# gather the pool through the table into the dense per-slot layout, run the
# IDENTICAL chunk forward, scatter the written blocks back. Every attended
# position is covered by a real table entry (the engine's allocator reserves
# scratch blocks before verify), so the indirection is numerically invisible
# — the dense-vs-paged parity tests assert bitwise-equal logits.
#
# Block 0 is the reserved null block: inactive rows and unused table entries
# point at it. Its contents are garbage, but garbage that is (a) never
# attended (masked beyond cache_len / key_limit) and (b) only ever written
# back with more garbage — the same overwrite-safety argument as the dense
# cache's masked rows.

def paged_gather(pool, block_table):
    """pool [L,2,NB,BS,H,Dh] + block_table [B,M] int32 -> dense
    [L,2,B,M*BS,H,Dh] logical view (M*BS must equal S_MAX)."""
    g = pool[:, :, block_table]                 # [L,2,B,M,BS,H,Dh]
    L, two, B, M, BS, H, Dh = g.shape
    return g.reshape(L, two, B, M * BS, H, Dh)


def paged_scatter(pool, block_table, dense):
    """Write a dense [L,2,B,S,H,Dh] logical view back into the pool through
    the table. Duplicate indices are (a) the null block 0 (inactive rows and
    unused entries — garbage racing over garbage) and (b) prefix-cache
    shared blocks mapped by several rows' tables: every sharing row
    writes back the identical committed bytes it gathered (verify only
    mutates positions at or beyond its own cache_len, which lie strictly
    above the shared prompt prefix), so the write order is immaterial."""
    L, two, B, S, H, Dh = dense.shape
    M = block_table.shape[1]
    blocks = dense.reshape(L, two, B, M, S // M, H, Dh)
    return pool.at[:, :, block_table].set(blocks)


def verify_paged(params, cfg: TargetConfig, chunk, cache_len, block_table,
                 pool):
    """Block-paged twin of `verify`: chunk [B,K+1] int32, cache_len [B] int32,
    block_table [B, S_MAX // BS] int32 pool-block ids, pool
    [L,2,NB,BS,H,Dh]. Returns (logits, feats, new_pool)."""
    dense = paged_gather(pool, block_table)
    logits, feats, new_dense = verify(params, cfg, chunk, cache_len, dense)
    return logits, feats, paged_scatter(pool, block_table, new_dense)


def verify_tree_paged(params, cfg: TargetConfig, chunk, cache_len,
                      block_table, pool, tree_mask, depths):
    """Block-paged twin of `verify_tree` (same mask/depth semantics)."""
    dense = paged_gather(pool, block_table)
    logits, feats, new_dense = verify_tree(params, cfg, chunk, cache_len,
                                           dense, tree_mask, depths)
    return logits, feats, paged_scatter(pool, block_table, new_dense)


def verify_tree_dyn_paged(params, cfg: TargetConfig, chunk, cache_len,
                          block_table, pool, tree_mask, depth_offsets):
    """Block-paged twin of `verify_tree_dyn` (same mask/depth semantics).

    The envelope scatter's inactive tail lands in blocks beyond the slot's
    table coverage — i.e. the reserved null block — which is exactly why the
    Rust allocator charges dynamic scratch by the node budget, not the
    envelope width (kv_cache.rs `chunk` vs `write_width`)."""
    dense = paged_gather(pool, block_table)
    logits, feats, new_dense = verify_tree_dyn(params, cfg, chunk, cache_len,
                                               dense, tree_mask, depth_offsets)
    return logits, feats, paged_scatter(pool, block_table, new_dense)


def zero_kv_paged(cfg: TargetConfig, num_blocks, block_size):
    return jnp.zeros(
        (cfg.n_layers, 2, num_blocks, block_size, cfg.n_heads, cfg.head_dim),
        jnp.float32,
    )


# ---------------------------------------------------------------------------
# In-place paged serving (device-resident decode; lowered by default)
# ---------------------------------------------------------------------------
#
# The gather-dense twins above materialize the WHOLE pool into the per-slot
# dense layout around every verify — two full-pool data movements per step
# that exist only to reuse `_chunk_forward`. The in-place twins below never
# densify: the chunk's K/V is scattered directly into the pool at
# (block_table[b, pos // BS], pos % BS), and attention runs through
# `kernels.paged_attention` — each (batch, head) program gathers exactly its
# own table's blocks (vLLM PagedAttention proper). Logits are BITWISE equal
# to the gather path's (the kernel computes the score rows over byte-equal
# gathered keys in sdpa's reduction order; pinned by
# tests/test_paged_kernel.py), and the new pool differs from the gather
# path's only in the reserved null block 0 (the gather path rewrites every
# covered block including null-mapped garbage; in-place writes only real
# chunk positions) — bytes no reachable logical view ever exposes.

def _chunk_forward_paged(params, cfg: TargetConfig, tokens, start, pool,
                         block_table, key_limit, pos_offsets=None,
                         chunk_mask=None):
    """`_chunk_forward` addressed through a block table: identical mask/RoPE
    construction over the logical view S = M*BS, chunk K/V scattered into
    pool blocks in place, attention via the Pallas paged kernel.

    tokens [B,T] int32; start [B] int32; pool [L,2,NB,BS,H,Dh];
    block_table [B,M] int32; key_limit/pos_offsets/chunk_mask as in
    `_chunk_forward`. Returns (features [B,T,3d], logits [B,T,V], new_pool).
    """
    from .kernels.paged_attention import paged_attention

    H, Dh = cfg.n_heads, cfg.head_dim
    B, T = tokens.shape
    BS = pool.shape[3]
    M = block_table.shape[1]
    S = M * BS  # logical view length (S_MAX for the serving configs)
    x = params["embed"][tokens]
    offs = (jnp.arange(T, dtype=jnp.int32) if pos_offsets is None
            else jnp.asarray(pos_offsets, jnp.int32))
    positions = start[:, None] + (offs if offs.ndim == 2 else offs[None, :])

    key_pos = jnp.arange(S, dtype=jnp.int32)
    allow = key_pos[None, None, :] < key_limit[:, :, None]
    if chunk_mask is not None:
        q_rel = key_pos[None, :] - start[:, None]              # [B, S]
        in_chunk = (q_rel >= 0) & (q_rel < T)
        q_clip = jnp.clip(q_rel, 0, T - 1)
        if chunk_mask.ndim == 3:
            gathered = jnp.take_along_axis(
                chunk_mask, jnp.broadcast_to(q_clip[:, None, :], (B, T, S)),
                axis=2)
            allow = allow | (gathered & in_chunk[:, None, :])
        else:
            gathered = chunk_mask[:, q_clip]                   # [T, B, S]
            allow = allow | (jnp.transpose(gathered, (1, 0, 2)) & in_chunk[:, None, :])
    bias = mask_to_bias(allow)[:, None]

    # chunk slot j lives at logical start + j -> pool (table[pos//BS], pos%BS)
    # (the same addressing `paged_scatter` uses, restricted to the chunk).
    # Collisions only happen in the null block 0 (inactive rows share it and
    # write identical PAD-chunk values), so the scatter order is immaterial.
    pos = start[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]  # [B, T]
    bid = jnp.take_along_axis(block_table, pos // BS, axis=1)       # [B, T]
    off = pos % BS

    taps = {i: None for i in cfg.feature_layers}
    new_kv = []
    for li, blk in enumerate(params["blocks"]):
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q = (h @ blk["wq"]).reshape(B, T, H, Dh)
        k = (h @ blk["wk"]).reshape(B, T, H, Dh)
        v = (h @ blk["wv"]).reshape(B, T, H, Dh)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

        k_pool = pool[li, 0].at[bid, off].set(k)   # [NB, BS, H, Dh]
        v_pool = pool[li, 1].at[bid, off].set(v)
        new_kv.append(jnp.stack([k_pool, v_pool]))

        a = paged_attention(
            q.transpose(0, 2, 1, 3), k_pool, v_pool, block_table, bias)
        a = a.transpose(0, 2, 1, 3).reshape(B, T, cfg.d_model)
        x = x + a @ blk["wo"]
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, blk["w_gate"], blk["w_up"], blk["w_down"])
        if li in taps:
            taps[li] = x

    feats = jnp.concatenate([taps[i] for i in cfg.feature_layers], axis=-1)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return feats, logits, jnp.stack(new_kv)


def verify_paged_inplace(params, cfg: TargetConfig, chunk, cache_len,
                         block_table, pool):
    """In-place twin of `verify_paged`: same signature, no densification."""
    B, T = chunk.shape
    key_limit = (cache_len[:, None]
                 + jnp.arange(1, T + 1, dtype=jnp.int32)[None, :])
    feats, logits, new_pool = _chunk_forward_paged(
        params, cfg, chunk, cache_len, pool, block_table, key_limit)
    return logits, feats, new_pool


def verify_tree_paged_inplace(params, cfg: TargetConfig, chunk, cache_len,
                              block_table, pool, tree_mask, depths):
    """In-place twin of `verify_tree_paged` (same mask/depth semantics)."""
    B, T = chunk.shape
    key_limit = jnp.broadcast_to(cache_len[:, None], (B, T))
    feats, logits, new_pool = _chunk_forward_paged(
        params, cfg, chunk, cache_len, pool, block_table, key_limit,
        pos_offsets=depths, chunk_mask=tree_mask != 0)
    return logits, feats, new_pool


def verify_tree_dyn_paged_inplace(params, cfg: TargetConfig, chunk, cache_len,
                                  block_table, pool, tree_mask,
                                  depth_offsets):
    """In-place twin of `verify_tree_dyn_paged` (same mask/depth semantics).

    The envelope scatter's inactive tail still lands through the table — for
    positions past the slot's coverage that is the reserved null block, same
    as `paged_scatter`'s argument."""
    B, T = chunk.shape
    key_limit = jnp.broadcast_to(cache_len[:, None], (B, T))
    feats, logits, new_pool = _chunk_forward_paged(
        params, cfg, chunk, cache_len, pool, block_table, key_limit,
        pos_offsets=depth_offsets, chunk_mask=tree_mask != 0)
    return logits, feats, new_pool


def commit_path_paged(plan, pool):
    """On-device accepted-path commit: apply block-mapped position copies to
    the pool without a host round trip.

    plan: [R, 4] int32 rows (src_block, src_off, dst_block, dst_off) — the
    PHYSICAL addresses of `plan_path_commit`'s copies, mapped through each
    slot's block table by the engine (rust/src/runtime/kv_blocks.rs
    `physical_copy_rows`); padding rows are (0, 0, 0, 0), an inert null-block
    self-copy. pool: [L,2,NB,BS,H,Dh]. Returns the committed pool.

    All sources are gathered from the INPUT pool before any write lands, so
    the result equals applying the copies sequentially (the host
    `apply_path_copies` semantics): within one slot, copy m's destination
    `base + m` is strictly below every later source `base + node` (node > m),
    and across slots the touched blocks are disjoint — no source is ever
    clobbered by an earlier destination, making gather-then-scatter and
    sequential application identical. Distinct real rows write distinct
    (block, offset) cells; padding rows all rewrite null cell (0, 0) with its
    own original value.
    """
    src = pool[:, :, plan[:, 0], plan[:, 1]]          # [L, 2, R, H, Dh]
    return pool.at[:, :, plan[:, 2], plan[:, 3]].set(src)


# ---------------------------------------------------------------------------
# Feature extraction for drafter training (full-sequence, no cache)
# ---------------------------------------------------------------------------

def target_features(params, cfg: TargetConfig, tokens):
    """tokens [B, S] -> (feats [B, S, 3d], logits [B, S, V]) — training-data
    generation for the drafter (the paper runs the frozen target over the
    corpus to collect hidden states)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    bias = mask_to_bias(jnp.tril(jnp.ones((S, S), bool)))[None, None]
    taps = {i: None for i in cfg.feature_layers}
    for li, blk in enumerate(params["blocks"]):
        x = run_block(x, blk, positions, bias, cfg.n_heads, cfg.rope_theta,
                      cfg.norm_eps)
        if li in taps:
            taps[li] = x
    feats = jnp.concatenate([taps[i] for i in cfg.feature_layers], axis=-1)
    logits = rms_norm(x, params["ln_f"], cfg.norm_eps) @ params["lm_head"]
    return feats, logits
