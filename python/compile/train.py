"""Drafter training with the paper's scalable long-context framework (§3).

Pipeline per step:
  1. sample a corpus mixture batch, run the frozen target to collect EAGLE-3
     features (build-time teacher pass);
  2. per example: COD-sample nested anchors, turn them into MTP training rows,
     fetch the attention mask — either as a gather over the PRECOMPUTED
     max-length mask (ours, §3.1) or rebuilt from scratch per example (PARD
     baseline, `mask_mode="pard"`);
  3. if the example exceeds the memory budget, Algorithm 1 partitions its rows
     into segments and gradients accumulate *within the sequence* (§3.2);
  4. micro-batch-1 gradient accumulation + Adam with the paper's linear
     warmup schedule.

The AR EAGLE-3 baseline trains depth-0 rows only, with EAGLE-3-style
Training-Time-Test passes (a second forward whose hidden inputs are the first
pass's own hiddens shifted by one row), which is also the HCA-flavored
alignment that makes the baseline strong.
"""

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from .configs import MASK_ID, DrafterConfig, TargetConfig, TrainConfig
from .drafter import init_drafter, train_rows_forward
from .masks import PrecomputedMask, cod_sample, pard_mask, rows_from_anchors
from .model import target_features
from .optim import adam_init, adam_update, linear_schedule
from .partition import partition_rows

PAD_BUCKET = 64


def _bucket(r):
    return ((r + PAD_BUCKET - 1) // PAD_BUCKET) * PAD_BUCKET


def max_rows(tc: TrainConfig):
    """Deterministic upper bound on rows per forward for a config — COD picks
    exactly round(m * r^d) anchors per depth, so the only variation is label
    clipping (which removes rows). Fixing the pad width keeps one jit shape
    for the whole run."""
    m = tc.seq_len - 2
    total = sum(int(round(m * tc.cod_ratio ** d)) for d in range(tc.k_train))
    if tc.segments > 1:
        # per-segment: owned rows ~ total/S (+ slack) + cumulative depth-0 keys
        per = total // tc.segments + tc.k_train * tc.segments + m
        total = min(total, per)
    return _bucket(total)


def prepare_example(tokens, feats, tc: TrainConfig, mask_src, rng, rp=None):
    """Build padded per-segment row batches for one example.

    tokens: [n] int32 numpy; feats: [n, 3dt] numpy.
    Returns list of dicts with keys matching drafter.train_rows_forward
    (leading dim 1).
    """
    n = len(tokens)
    m = n - 2                      # row space (see drafter.py docstring)
    k = tc.k_train
    anchors = cod_sample(m, k, tc.cod_ratio, rng)

    if tc.segments <= 1:
        rows = rows_from_anchors(anchors, m, k)
        seg_sets = [(rows, np.zeros(0, np.int64))]
    else:
        part = partition_rows(anchors, m, k, tc.segments)
        seg_sets = list(zip(part.segment_rows, part.segment_extra_keys))

    out = []
    for owned, extra in seg_sets:
        if len(owned) == 0:
            continue
        rows = np.sort(np.concatenate([owned, extra]))
        owned_set = set(owned.tolist())
        R = len(rows)
        Rp = rp if rp is not None else _bucket(R)
        assert R <= Rp, (R, Rp)

        p = rows // k
        d = rows % k
        tok_in = np.where(d == 0, tokens[p + 1], MASK_ID).astype(np.int32)
        # depth-0 rows carry feat_p; MTP rows carry the anchor's features
        feat = feats[np.where(d == 0, p, p - d)]
        label = tokens[p + 2].astype(np.int32)
        loss_w = np.array([1.0 if r in owned_set else 0.0 for r in rows],
                          np.float32)

        if tc.mask_mode == "pard":
            mask = pard_mask(rows, k)          # O(R^2) from-scratch (baseline)
        else:
            mask = mask_src.gather(rows)       # amortized: O(1) view + gather

        b = {
            "tok_in": np.zeros(Rp, np.int32),
            "depth": np.zeros(Rp, np.int32),
            "pos": np.zeros(Rp, np.int32),
            "feat": np.zeros((Rp, feats.shape[-1]), np.float32),
            "label": np.zeros(Rp, np.int32),
            "loss_w": np.zeros(Rp, np.float32),
            "valid": np.zeros(Rp, bool),
            "mask": np.zeros((Rp, Rp), bool),
        }
        b["tok_in"][:R] = tok_in
        b["depth"][:R] = d
        b["pos"][:R] = p
        b["feat"][:R] = feat
        b["label"][:R] = label
        b["loss_w"][:R] = loss_w
        b["valid"][:R] = True
        b["mask"][:R, :R] = mask
        out.append({kk: vv[None] for kk, vv in b.items()})
    return out


def prepare_ar_example(tokens, feats, rp=None):
    """Depth-0-only rows for the AR EAGLE-3 baseline (causal mask)."""
    n = len(tokens)
    m = n - 2
    Rp = rp if rp is not None else _bucket(m)
    p = np.arange(m)
    b = {
        "tok_in": np.zeros(Rp, np.int32),
        "depth": np.zeros(Rp, np.int32),
        "pos": np.zeros(Rp, np.int32),
        "feat": np.zeros((Rp, feats.shape[-1]), np.float32),
        "label": np.zeros(Rp, np.int32),
        "loss_w": np.zeros(Rp, np.float32),
        "valid": np.zeros(Rp, bool),
        "mask": np.zeros((Rp, Rp), bool),
    }
    b["tok_in"][:m] = tokens[1:m + 1]
    b["pos"][:m] = p
    b["feat"][:m] = feats[:m]
    b["label"][:m] = tokens[2:m + 2]
    b["loss_w"][:m] = 1.0
    b["valid"][:m] = True
    b["mask"][:m, :m] = np.tril(np.ones((m, m), bool))
    return [{kk: vv[None] for kk, vv in b.items()}]


def _freeze_embed_grads(grads):
    return {**grads, "embed": jnp.zeros_like(grads["embed"])}


def train_drafter(target_params, tcfg: TargetConfig, dcfg: DrafterConfig,
                  tc: TrainConfig, snapshot_steps=(), verbose=True):
    """Train one drafter variant. Returns (params, log, snapshots dict)."""
    key = jax.random.PRNGKey(tc.seed + abs(hash(dcfg.name)) % 100000)
    params = init_drafter(key, dcfg, tcfg, target_embed=target_params["embed"])
    opt = adam_init(params)
    rng = np.random.default_rng(tc.seed + 13)
    regimes = {n: data_mod.MarkovRegime(n) for n in data_mod.REGIMES}

    # §3.1: ONE-time mask construction for the maximum sequence length.
    mask_src = None
    if tc.mask_mode != "pard":
        mask_src = PrecomputedMask(tc.seq_len, tc.k_train)

    feat_fn = jax.jit(lambda toks: target_features(target_params, tcfg, toks))

    is_ar = dcfg.kind == "ar"
    grad_fn = jax.jit(jax.value_and_grad(
        lambda prm, batch, dk: train_rows_forward(prm, dcfg, batch, dk),
        has_aux=True))

    if is_ar:
        # TTT pass: hidden inputs = previous pass's own hiddens, shifted
        def ttt_loss(prm, batch, h_prev):
            h_shift = jnp.concatenate(
                [ (batch["feat"][:, :1] @ prm["proj_feat"]), h_prev[:, :-1] ],
                axis=1)
            return train_rows_forward(prm, dcfg, batch, None,
                                      h_override=h_shift)
        ttt_grad_fn = jax.jit(jax.value_and_grad(ttt_loss, has_aux=True))

    @jax.jit
    def apply(params, opt, grads, lr_now):
        return adam_update(params, grads, opt, lr_now)

    def tree_add(a, b):
        return jax.tree_util.tree_map(jnp.add, a, b)

    log = {"steps": [], "loss": [], "acc": [], "ntp_acc": [], "mtp_acc": [],
           "alpha": [], "data_prep_s": 0.0, "train_s": 0.0}
    snapshots = {}
    t0 = time.time()

    rp = _bucket(tc.seq_len - 2) if is_ar else max_rows(tc)

    for s in range(tc.steps):
        # --- data: corpus batch + teacher features -----------------------
        tp0 = time.time()
        toks = data_mod.training_batch(regimes, tc.batch, tc.seq_len, rng)
        feats, _ = feat_fn(jnp.asarray(toks, jnp.int32))
        feats = np.asarray(feats)
        micro = []
        for i in range(tc.batch):
            if is_ar:
                micro += prepare_ar_example(toks[i], feats[i], rp=rp)
            else:
                micro += prepare_example(toks[i], feats[i], tc, mask_src, rng,
                                         rp=rp)
        log["data_prep_s"] += time.time() - tp0

        # --- stacked micro-batches: same fixed row shape, one XLA call.
        # (Paper memory semantics preserved — gradient summation over
        # micro-batches/segments is associative; stacking trades the paper's
        # sequential accumulation for single-core throughput.) -------------
        tt0 = time.time()
        batch = {kk: jnp.asarray(np.concatenate([m[kk] for m in micro]))
                 for kk in micro[0]}
        dk = jax.random.fold_in(key, s)
        (loss, aux), grads = grad_fn(params, batch, dk)
        if is_ar and tc.ttt_passes > 1:
            (l2, _), g2 = ttt_grad_fn(params, batch,
                                      jax.lax.stop_gradient(aux["hidden"]))
            grads = tree_add(grads, g2)
            loss = (loss + l2) / 2.0
        if dcfg.freeze_embeddings:
            grads = _freeze_embed_grads(grads)
        lr_now = linear_schedule(
            s, tc.steps, tc.lr, max(10, int(tc.steps * tc.warmup_ratio)))
        params, opt = apply(params, opt, grads, lr_now)
        log["train_s"] += time.time() - tt0

        if s % 20 == 0 or s == tc.steps - 1:
            log["steps"].append(s)
            log["loss"].append(float(loss))
            log["acc"].append(float(aux["acc"]))
            log["ntp_acc"].append(float(aux["ntp_acc"]))
            log["mtp_acc"].append(float(aux["mtp_acc"]))
            if "alpha" in params:
                log["alpha"].append(float(params["alpha"]))
            if verbose and (s % 100 == 0 or s == tc.steps - 1):
                print(f"  [{dcfg.name}] step {s:4d} loss {float(loss):.4f} "
                      f"acc {float(aux['acc']):.3f} mtp {float(aux['mtp_acc']):.3f} "
                      f"({time.time()-t0:.1f}s)")
        if (s + 1) in snapshot_steps:
            snapshots[s + 1] = jax.tree_util.tree_map(lambda x: x, params)
    return params, log, snapshots
