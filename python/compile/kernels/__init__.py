# L1: Pallas kernel(s) for the paper's compute hot-spot.
from . import ref  # noqa: F401
from . import draft_attention  # noqa: F401
