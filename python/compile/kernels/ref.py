"""Pure-jnp oracle for the Pallas draft-attention kernel.

This is the correctness contract: `draft_attention.draft_attention(...)` must
match `ref_attention(...)` to float32 tolerance for every shape/dtype the
hypothesis sweep in python/tests/test_kernel.py generates.
"""

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def ref_attention(q, k, v, bias):
    """q: [B,H,T,Dh], k/v: [B,H,S,Dh], bias: [B,1,T,S] or [1,1,T,S] additive.

    Plain softmax(QK^T/sqrt(d) + bias) V in float32.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale + bias.astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_paged_attention(q, k_pool, v_pool, block_table, bias):
    """Oracle for the in-place paged kernel: densify the pool through the
    table (exactly `model.paged_gather`'s addressing), then plain attention.

    q: [B,H,T,Dh]; k_pool, v_pool: [NB,BS,H,Dh] (one layer's pool planes);
    block_table: [B,M] int32 pool-block ids; bias: [B,1,T,S] or [1,1,T,S]
    additive with S = M*BS.
    """
    B = q.shape[0]
    BS, H, Dh = k_pool.shape[1], k_pool.shape[2], k_pool.shape[3]
    M = block_table.shape[1]
    # [B,M,BS,H,Dh] -> [B,S,H,Dh] -> [B,H,S,Dh]
    k = k_pool[block_table].reshape(B, M * BS, H, Dh).transpose(0, 2, 1, 3)
    v = v_pool[block_table].reshape(B, M * BS, H, Dh).transpose(0, 2, 1, 3)
    return ref_attention(q, k, v, bias)


def ref_attention_varlen(q, k, v, bias, kv_len):
    """Variant with a per-batch valid key length (serving verify path):
    keys at s >= kv_len[b] are masked out on top of `bias`.

    kv_len: [B] int32.
    """
    S = k.shape[2]
    key_ok = jnp.arange(S)[None, :] < kv_len[:, None]      # [B,S]
    extra = jnp.where(key_ok, 0.0, NEG_INF)[:, None, None, :]
    return ref_attention(q, k, v, bias + extra)
