"""Pure-jnp oracle for the Pallas draft-attention kernel.

This is the correctness contract: `draft_attention.draft_attention(...)` must
match `ref_attention(...)` to float32 tolerance for every shape/dtype the
hypothesis sweep in python/tests/test_kernel.py generates.
"""

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e9


def ref_attention(q, k, v, bias):
    """q: [B,H,T,Dh], k/v: [B,H,S,Dh], bias: [B,1,T,S] or [1,1,T,S] additive.

    Plain softmax(QK^T/sqrt(d) + bias) V in float32.
    """
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum(
        "bhtd,bhsd->bhts", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale + bias.astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_attention_varlen(q, k, v, bias, kv_len):
    """Variant with a per-batch valid key length (serving verify path):
    keys at s >= kv_len[b] are masked out on top of `bias`.

    kv_len: [B] int32.
    """
    S = k.shape[2]
    key_ok = jnp.arange(S)[None, :] < kv_len[:, None]      # [B,S]
    extra = jnp.where(key_ok, 0.0, NEG_INF)[:, None, None, :]
    return ref_attention(q, k, v, bias + extra)
