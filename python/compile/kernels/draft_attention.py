"""Pallas fused draft-attention kernel — the L1 hot-spot.

This is the attention inside the P-EAGLE drafter forward pass: queries are
the `C + K - 1` rows `[context pairs | MTP slots]`, keys/values are either the
same rows (chain drafting is plain causal attention over the window — see
DESIGN.md) or, for the flash variant, a longer key set. One fused kernel
computes QK^T -> +bias -> softmax -> V without materializing the score matrix
in HBM.

Hardware adaptation (paper targets H200 CUDA; see DESIGN.md
§Hardware-Adaptation): instead of a warp/threadblock decomposition we tile for
the TPU memory hierarchy — the grid iterates (batch, head, q-tile), each
program instance holding one q-tile plus streamed k/v tiles in VMEM and
accumulating with the online-softmax recurrence so the VMEM footprint is
O(Tq*Dh + Ts*Dh + Tq*Ts) independent of S. Tile sizes default to MXU-friendly
(8, 128)-aligned shapes, padded up when the problem is smaller.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the same kernel runs
inside the AOT artifacts loaded by the Rust runtime. Real-TPU VMEM/MXU
estimates are derived analytically in EXPERIMENTS.md §Perf.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _single_block_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale):
    """One (batch, head) program instance: full T x S attention in VMEM.

    Used when T*S fits a single tile (the drafter window path: T,S <= ~32).
    """
    q = q_ref[...].astype(jnp.float32)           # [T, Dh]
    k = k_ref[...].astype(jnp.float32)           # [S, Dh]
    v = v_ref[...].astype(jnp.float32)           # [S, Dh]
    b = bias_ref[...].astype(jnp.float32)        # [T, S]
    scores = q @ k.T * scale + b                 # [T, S] (MXU matmul)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (p @ v).astype(o_ref.dtype)     # [T, Dh] (MXU matmul)


def draft_attention(q, k, v, bias, *, interpret=True):
    """Fused attention, single-block per (batch, head).

    q: [B,H,T,Dh]; k,v: [B,H,S,Dh]; bias: [B,1,T,S] or [1,1,T,S] additive.
    Returns [B,H,T,Dh] in q.dtype. Matches kernels.ref.ref_attention.
    """
    B, H, T, Dh = q.shape
    S = k.shape[2]
    scale = 1.0 / math.sqrt(Dh)
    bias_b = jnp.broadcast_to(bias, (B, 1, T, S))

    kernel = functools.partial(_single_block_kernel, scale=scale)
    grid = (B, H)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((None, None, T, S), lambda b, h: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, T, Dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v, bias_b)


# ---------------------------------------------------------------------------
# Flash variant: streamed K/V tiles with online softmax (for long key sets,
# e.g. the verify path's S_MAX=256 cache). Grid = (B, H, num_q_tiles); the
# k-loop runs inside the kernel so the score matrix never exceeds one tile.
# ---------------------------------------------------------------------------

def _flash_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, *, scale, ts):
    q = q_ref[...].astype(jnp.float32)                     # [Tq, Dh]
    S = k_ref.shape[0]
    Tq, Dh = q.shape
    nk = S // ts

    def body(i, carry):
        acc, m_prev, l_prev = carry
        kk = jax.lax.dynamic_slice_in_dim(k_ref[...], i * ts, ts, 0)
        vv = jax.lax.dynamic_slice_in_dim(v_ref[...], i * ts, ts, 0)
        bb = jax.lax.dynamic_slice_in_dim(bias_ref[...], i * ts, ts, 1)
        s = q @ kk.astype(jnp.float32).T * scale + bb.astype(jnp.float32)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + p @ vv.astype(jnp.float32)
        return acc, m_new, l_new

    acc0 = jnp.zeros((Tq, Dh), jnp.float32)
    m0 = jnp.full((Tq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((Tq, 1), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, nk, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def draft_attention_flash(q, k, v, bias, *, tq=8, ts=128, interpret=True):
    """Flash-style fused attention with streamed K/V tiles.

    q: [B,H,T,Dh]; k,v: [B,H,S,Dh]; bias: broadcastable [.,1,T,S].
    T must be divisible by tq and S by ts (callers pad; NEG_INF bias masks
    padding). VMEM per program instance ≈ (tq + 2*ts)*Dh + tq*ts floats.
    """
    B, H, T, Dh = q.shape
    S = k.shape[2]
    assert T % tq == 0 and S % ts == 0, (T, tq, S, ts)
    scale = 1.0 / math.sqrt(Dh)
    bias_b = jnp.broadcast_to(bias, (B, 1, T, S))

    kernel = functools.partial(_flash_kernel, scale=scale, ts=ts)
    grid = (B, H, T // tq)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, tq, Dh), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((None, None, S, Dh), lambda b, h, t: (b, h, 0, 0)),
            pl.BlockSpec((None, None, tq, S), lambda b, h, t: (b, 0, t, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, tq, Dh), lambda b, h, t: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        interpret=interpret,
    )(q, k, v, bias_b)


def vmem_estimate_bytes(tq, ts, dh, dtype_bytes=4):
    """Analytical VMEM footprint per program instance of the flash kernel
    (used for the §Perf TPU estimates — interpret mode has no real VMEM)."""
    return dtype_bytes * (tq * dh + 2 * ts * dh + tq * ts + 3 * tq + tq * dh)


def mxu_utilization_estimate(t, s, dh, tq=8, ts=128):
    """Fraction of MXU work that is non-padding for a T x S attention with
    (tq, ts) tiles: real FLOPs / padded-tile FLOPs."""
    import math as _m

    pt = _m.ceil(t / tq) * tq
    ps = _m.ceil(s / ts) * ts
    real = t * s * dh * 2 * 2
    padded = pt * ps * dh * 2 * 2
    return real / padded
