"""Pallas paged-attention kernel — in-place attention over the KV block pool.

This is the verify-path attention of the device-resident decode step: instead
of densifying the block pool into the per-slot `[B, S_MAX, ...]` layout
before attending (`model.paged_gather`, one full-pool gather + scatter per
verify), each (batch, head) program instance walks its OWN row of the block
table and gathers exactly the `M = S_MAX / BS` pool blocks that hold the
slot's logical cache — vLLM PagedAttention proper, adapted to the TPU memory
hierarchy (see DESIGN.md §Hardware-Adaptation): the per-instance working set
is the gathered `[S, Dh]` K/V pair plus the `[T, S]` score tile in VMEM, and
`num_blocks` bounds the *device* pool footprint, not just the accounting.

Numerics contract: the gathered key/value rows are byte-identical to what
`paged_gather` would have materialized (same pool bytes addressed through the
same table), the score matrix is computed in one full-row `[T, S]` tile, and
the softmax reduces in the same order as `common.sdpa`'s — so logits from the
in-place verify twins are BITWISE equal to the gather-dense path's
(python/tests/test_paged_kernel.py pins this across chain/tree/dyn). The
flash/online-softmax variant in draft_attention.py deliberately does NOT
carry that guarantee, which is why this kernel keeps the single-tile shape.

`interpret=True` for the same reason as draft_attention.py: the CPU PJRT
plugin cannot execute Mosaic custom-calls, and interpret mode lowers the
kernel — block-table gather included — to plain HLO that runs inside the AOT
artifacts loaded by the Rust runtime.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e9


def _paged_block_kernel(table_ref, q_ref, kp_ref, vp_ref, bias_ref, o_ref, *,
                        scale):
    """One (batch, head) program instance: gather the slot's blocks, then
    full T x S attention in VMEM (same math as
    draft_attention._single_block_kernel, keys addressed through the table).
    """
    t = table_ref[...]                           # [M] pool-block ids
    q = q_ref[...].astype(jnp.float32)           # [T, Dh]
    kp = kp_ref[...].astype(jnp.float32)         # [NB, BS, Dh] (this head)
    vp = vp_ref[...].astype(jnp.float32)
    b = bias_ref[...].astype(jnp.float32)        # [T, S]
    bs, dh = kp.shape[1], kp.shape[2]
    k = kp[t].reshape(t.shape[0] * bs, dh)       # [S, Dh] through the table
    v = vp[t].reshape(t.shape[0] * bs, dh)
    scores = q @ k.T * scale + b                 # [T, S] (MXU matmul)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (p @ v).astype(o_ref.dtype)     # [T, Dh] (MXU matmul)


def paged_attention(q, k_pool, v_pool, block_table, bias, *, interpret=True):
    """In-place attention over a paged KV pool, single block per (batch, head).

    q: [B,H,T,Dh]; k_pool, v_pool: [NB,BS,H,Dh] (one layer's pool planes);
    block_table: [B,M] int32 pool-block ids (M*BS = the logical view length
    S); bias: [B,1,T,S] or [1,1,T,S] additive. Returns [B,H,T,Dh] in q.dtype.
    Matches kernels.ref.ref_paged_attention bitwise.
    """
    B, H, T, Dh = q.shape
    NB, BS = k_pool.shape[0], k_pool.shape[1]
    M = block_table.shape[1]
    S = M * BS
    scale = 1.0 / math.sqrt(Dh)
    bias_b = jnp.broadcast_to(bias, (B, 1, T, S))

    kernel = functools.partial(_paged_block_kernel, scale=scale)
    grid = (B, H)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, M), lambda b, h: (b, 0)),
            pl.BlockSpec((None, None, T, Dh), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((NB, BS, None, Dh), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((NB, BS, None, Dh), lambda b, h: (0, 0, h, 0)),
            pl.BlockSpec((None, None, T, S), lambda b, h: (b, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, T, Dh), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T, Dh), q.dtype),
        interpret=interpret,
    )(block_table, q, k_pool, v_pool, bias_b)


def paged_vmem_estimate_bytes(m, bs, t, dh, dtype_bytes=4):
    """Analytical VMEM footprint per program instance on a real TPU (the
    §Perf estimate; interpret mode has no real VMEM): the gathered [S, Dh]
    K and V tiles, the [T, S] score tile, and the q/o tiles. The whole-pool
    operand streams through HBM — only the table-named blocks are pulled."""
    s = m * bs
    return dtype_bytes * (2 * s * dh + t * s + 2 * t * dh + m)
