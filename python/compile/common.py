"""Shared jnp building blocks: RMSNorm, RoPE, masked MHA, SwiGLU, init.

Everything here is pure-functional over explicit parameter pytrees so the same
code paths serve training (grad), AOT lowering, and the pure-jnp kernel oracle.
"""

import math
from functools import partial

import jax
import jax.numpy as jnp

NEG_INF = -1e9


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(key, fan_in, fan_out, scale=1.0):
    std = scale / math.sqrt(fan_in)
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * std


def embed_init(key, vocab, dim):
    return jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * weight


def swiglu(x, w_gate, w_up, w_down):
    return (jax.nn.silu(x @ w_gate) * (x @ w_up)) @ w_down


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta=10000.0):
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return inv  # [head_dim/2]


def apply_rope(x, positions, theta=10000.0):
    """x: [..., T, H, Dh]; positions: [..., T] int32 (broadcastable)."""
    head_dim = x.shape[-1]
    inv = rope_freqs(head_dim, theta)                       # [Dh/2]
    ang = positions[..., None].astype(jnp.float32) * inv    # [..., T, Dh/2]
    cos = jnp.cos(ang)[..., None, :]                        # [..., T, 1, Dh/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ---------------------------------------------------------------------------
# Attention (reference path; the Pallas kernel mirrors this math)
# ---------------------------------------------------------------------------

def sdpa(q, k, v, bias):
    """q: [B,H,T,Dh], k/v: [B,H,S,Dh], bias: broadcastable to [B,H,T,S]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) * scale + bias
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhts,bhsd->bhtd", probs, v)


def mha(x, params, positions, bias, n_heads, theta=10000.0, kv=None):
    """Multi-head attention over x with RoPE.

    x: [B,T,D]; positions: [B,T]; bias: [B,1,T,S] additive.
    kv: optional (k_ext, v_ext) each [B,S,H,Dh] of *pre-roped* external
        keys/values the queries should attend to instead of x's own K/V
        (used by the KV-cache serving path). When None, S == T.
    Returns [B,T,D].
    """
    B, T, D = x.shape
    H = n_heads
    Dh = D // H
    q = (x @ params["wq"]).reshape(B, T, H, Dh)
    q = apply_rope(q, positions, theta)
    if kv is None:
        k = (x @ params["wk"]).reshape(B, T, H, Dh)
        v = (x @ params["wv"]).reshape(B, T, H, Dh)
        k = apply_rope(k, positions, theta)
    else:
        k, v = kv
    out = sdpa(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3), bias
    )
    out = out.transpose(0, 2, 1, 3).reshape(B, T, D)
    return out @ params["wo"]


def causal_bias(T, dtype=jnp.float32):
    m = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(m, 0.0, NEG_INF).astype(dtype)[None, None]


def mask_to_bias(mask_bool):
    """bool mask (True = may attend) -> additive bias."""
    return jnp.where(mask_bool, 0.0, NEG_INF).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Transformer block (shared by target and drafter)
# ---------------------------------------------------------------------------

def init_block(key, d_model, n_heads, ffn_dim):
    ks = jax.random.split(key, 7)
    return {
        "ln1": jnp.ones((d_model,), jnp.float32),
        "wq": dense_init(ks[0], d_model, d_model),
        "wk": dense_init(ks[1], d_model, d_model),
        "wv": dense_init(ks[2], d_model, d_model),
        "wo": dense_init(ks[3], d_model, d_model),
        "ln2": jnp.ones((d_model,), jnp.float32),
        "w_gate": dense_init(ks[4], d_model, ffn_dim),
        "w_up": dense_init(ks[5], d_model, ffn_dim),
        "w_down": dense_init(ks[6], ffn_dim, d_model),
    }


def run_block(x, blk, positions, bias, n_heads, theta, eps, kv=None,
              attn_fn=None):
    """One pre-norm transformer block. attn_fn optionally overrides the
    attention inner product (the Pallas kernel hooks in here)."""
    h = rms_norm(x, blk["ln1"], eps)
    if attn_fn is None:
        a = mha(h, blk, positions, bias, n_heads, theta, kv=kv)
    else:
        a = attn_fn(h, blk, positions, bias, n_heads, theta, kv)
    x = x + a
    h = rms_norm(x, blk["ln2"], eps)
    x = x + swiglu(h, blk["w_gate"], blk["w_up"], blk["w_down"])
    return x


def cross_entropy(logits, labels, valid=None):
    """Mean CE over valid positions. logits [..., V], labels [...] int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if valid is None:
        return jnp.mean(nll)
    valid = valid.astype(jnp.float32)
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)
