"""Algorithm 1 — dependency-preserving sequence partitioning (paper §3.2).

Splits one training example's sampled rows into S segments for
*within-sequence gradient accumulation*: each segment is processed by a
separate forward/backward pass and gradients are summed. The partition must
preserve every attention dependency:

  * chain: row (p, d) attends (p-1, d-1) ... — Phase 2 propagates the segment
    assignment of a row's chain parent, so whole chains stay together;
  * context: row (p, d) attends depth-0 rows q <= p - d — Phase 3 includes
    depth-0 rows *cumulatively* up to each segment boundary as extra keys
    (keys only: their loss is owned by their home segment).

With those two closures, per-row attention outputs (and hence summed
gradients) are bitwise the training-math equal of the unpartitioned pass —
property-tested in python/tests/test_partition.py and rust/src/partition.
"""

from dataclasses import dataclass
from typing import List

import numpy as np


@dataclass
class Partition:
    """Result of Algorithm 1 over one example."""

    # per-segment arrays of interleaved row ids (p*k+d) that OWN loss there
    segment_rows: List[np.ndarray]
    # per-segment arrays of depth-0 row ids included as keys only (Phase 3
    # cumulative context), disjoint from segment_rows
    segment_extra_keys: List[np.ndarray]
    boundaries: np.ndarray  # segment boundaries over positions, len S+1

    @property
    def n_segments(self):
        return len(self.segment_rows)


def partition_rows(anchors, n, k, s):
    """Algorithm 1 (paper pseudocode, in anchor coordinates).

    anchors: nested COD anchor sets (masks.cod_sample); n: sequence length;
    k: depths; s: number of segments. Returns a Partition over the row ids of
    masks.rows_from_anchors(anchors, n, k).
    """
    # 1-2: segment boundaries over positions
    bounds = np.array([(i * n) // s for i in range(s + 1)], dtype=np.int64)

    assign = {}  # (p, d) -> segment

    # Phase 1: depths 0 and 1 assigned by position p
    for d in (0, 1):
        if d >= k:
            break
        for a in anchors[d]:
            p = a + d
            if p > n - 2:
                continue
            seg = int(np.searchsorted(bounds, p, side="right") - 1)
            seg = min(seg, s - 1)
            assign[(p, d)] = seg

    # Phase 2: depths >= 2 inherit from their chain parent (p-1, d-1)
    for d in range(2, k):
        for a in anchors[d]:
            p = a + d
            if p > n - 2:
                continue
            parent = (p - 1, d - 1)
            if parent in assign:
                assign[(p, d)] = assign[parent]
            else:
                # parent row was label-clipped (p-1 == n-1 can't happen since
                # p <= n-2; parent missing only if anchors not nested —
                # guarded against, but fall back to positional assignment)
                seg = int(np.searchsorted(bounds, p, side="right") - 1)
                assign[(p, d)] = min(seg, s - 1)

    seg_rows = [[] for _ in range(s)]
    for (p, d), seg in assign.items():
        seg_rows[seg].append(p * k + d)
    segment_rows = [np.sort(np.array(r, dtype=np.int64)) for r in seg_rows]

    # Phase 3: cumulative depth-0 keys up to each segment's boundary
    d0 = np.array(
        [p * k for p in anchors[0] if p <= n - 2], dtype=np.int64
    )
    extra = []
    for seg in range(s):
        own = set(segment_rows[seg].tolist())
        upto = bounds[seg + 1]
        cum = np.array([r for r in d0 if (r // k) < upto and r not in own],
                       dtype=np.int64)
        extra.append(np.sort(cum))
    return Partition(segment_rows=segment_rows, segment_extra_keys=extra,
                     boundaries=bounds)


def validate_partition(part: Partition, anchors, n, k):
    """Check the paper's invariants. Returns list of violation strings."""
    from .masks import rows_from_anchors

    errs = []
    all_rows = set(rows_from_anchors(anchors, n, k).tolist())
    seen = {}
    for s, rows in enumerate(part.segment_rows):
        for r in rows:
            if r in seen:
                errs.append(f"row {r} owned by segments {seen[r]} and {s}")
            seen[r] = s
    if set(seen) != all_rows:
        missing = all_rows - set(seen)
        extra = set(seen) - all_rows
        errs.append(f"ownership mismatch: missing={sorted(missing)[:5]} "
                    f"extra={sorted(extra)[:5]}")

    # every owned row's full attention set must be present in its segment
    for s, rows in enumerate(part.segment_rows):
        keys = set(rows.tolist()) | set(part.segment_extra_keys[s].tolist())
        for r in rows:
            p, d = r // k, r % k
            # chain parents
            for e in range(d):
                q = p - d + e
                rid = q * k + e
                if rid in all_rows and rid not in keys:
                    errs.append(f"seg {s}: row ({p},{d}) missing chain ({q},{e})")
            # depth-0 context
            for q in range(p - d + 1):
                rid = q * k
                if rid in all_rows and rid not in keys:
                    errs.append(f"seg {s}: row ({p},{d}) missing ctx ({q},0)")
                    break  # one per row is enough signal
    return errs
