"""AOT pipeline: pretrain targets, train every drafter variant, lower all
serving executables to HLO *text*, and emit artifacts/manifest.json.

HLO text (not serialized HloModuleProto) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which the runtime's xla_extension
0.5.1 rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Stages are individually cached under artifacts/ so a partial run resumes:
  weights/<name>.pew + logs/<name>.json   — training outputs
  hlo/<exec>.hlo.txt                      — lowered executables
  manifest.json                           — written last (Make's stamp)

Env knobs:
  PEAGLE_FAST=1       quarter training steps (CI / iteration)
  PEAGLE_KERNEL=jnp   lower drafters with the jnp attention instead of the
                      Pallas kernel (perf A/B in EXPERIMENTS.md §Perf)
  PEAGLE_PAGED_GATHER=1  lower the paged verify families on the legacy
                      gather-dense path (paged_gather densification) instead
                      of the in-place paged-attention kernel — parity baseline
                      for python/tests/test_paged_kernel.py
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from .configs import (
    BATCH_SIZES, BOS_ID, COMMIT_PLAN_ROWS, CTX_WINDOW, DATASETS, DEFAULT_K,
    EOS_ID, EPOCH_SNAPSHOTS, KV_BLOCK_SIZE, MASK_ID, PAD_ID, PREFIX_TAIL_PAD,
    PROMPT_PAD, S_MAX, SPEC_DEPTHS, TABLE1_CONTEXTS, TARGETS,
    TREE_DYN_ENVELOPES, TREE_TARGETS, TREE_TOPOLOGIES, VOCAB, DrafterConfig,
    all_drafters, ablation_drafters, config_dict, drafter_modes,
    drafter_train_config, kv_blocks_per_slot, num_kv_blocks,
    serving_drafters, table1_drafters, tree_drafters,
)
from .drafter import draft_ar, draft_pe, draft_pe_tree, init_drafter
from .masks import tree_depths, tree_topology_id
from .model import (
    commit_path_paged, init_target, prefill, prefill_cached, verify,
    verify_paged, verify_paged_inplace, verify_tree, verify_tree_dyn,
    verify_tree_dyn_paged, verify_tree_dyn_paged_inplace, verify_tree_paged,
    verify_tree_paged_inplace, zero_kv,
)
from .pew import flatten_named, read_pew, unflatten_named, write_pew
from .pretrain import pretrain_target
from .train import train_drafter

FAST = os.environ.get("PEAGLE_FAST", "") == "1"
KERNEL = os.environ.get("PEAGLE_KERNEL", "pallas")
# Legacy paged lowering: densify through paged_gather before attending.
# Default (off) lowers the paged verify families on the in-place Pallas
# paged-attention kernel — no densification, same names/kinds, bitwise-equal
# logits (the manifest records which path was lowered as `paged_inplace`).
PAGED_GATHER = os.environ.get("PEAGLE_PAGED_GATHER", "") == "1"


def to_hlo_text(lowered) -> str:
    # return_tuple=False => with the runtime's untuple_result patch each
    # result comes back as its own output buffer, so the Rust engine can
    # thread the KV cache buffers straight into the next call without host
    # round-trips.
    #
    # print_large_constants=True is ESSENTIAL: the default printer elides
    # array constants over ~10 elements as `{...}`, which the text parser
    # silently reads back as zeros (e.g. RoPE frequency tables become
    # pow(theta, 0) == 1 — wrong numerics with no error).
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "elided constant survived — HLO text is lossy"
    return text


def lower_to_file(fn, args, path):
    # keep_unused=True: jit otherwise PRUNES parameters a variant doesn't
    # touch (e.g. h_shared in the AR drafter), silently shifting every
    # subsequent argument position away from the manifest's param_order.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def spec_of(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(np.shape(a), np.asarray(a).dtype), tree)


def io_spec(arrs):
    return [
        {"dtype": str(np.asarray(a).dtype), "shape": list(np.shape(a))}
        for a in arrs
    ]


class Artifacts:
    def __init__(self, root):
        self.root = root
        for sub in ("weights", "hlo", "logs", "eval"):
            os.makedirs(os.path.join(root, sub), exist_ok=True)
        self.manifest = {
            "vocab": VOCAB, "s_max": S_MAX, "prompt_pad": PROMPT_PAD,
            "ctx_window": CTX_WINDOW, "pad_id": PAD_ID, "bos_id": BOS_ID,
            "eos_id": EOS_ID, "mask_id": MASK_ID,
            "spec_depths": SPEC_DEPTHS, "batch_sizes": BATCH_SIZES,
            "default_k": DEFAULT_K, "kv_block_size": KV_BLOCK_SIZE,
            "prefix_tail_pad": PREFIX_TAIL_PAD,
            "kernel": KERNEL, "fast": FAST,
            "paged_inplace": not PAGED_GATHER,
            "commit_plan_rows": COMMIT_PLAN_ROWS,
            "targets": {}, "drafters": {}, "executables": [],
            "regimes": {}, "eval_prompts": {}, "training_logs": {},
            "table1_contexts": {str(k): v for k, v in TABLE1_CONTEXTS.items()},
        }

    def path(self, *parts):
        return os.path.join(self.root, *parts)

    def save_params(self, name, params):
        tensors, _ = flatten_named(params)
        write_pew(self.path("weights", f"{name}.pew"), tensors)
        return [n for n, _ in tensors]

    def load_params(self, name, template):
        tensors = read_pew(self.path("weights", f"{name}.pew"))
        return unflatten_named(tensors, template)

    def has_weights(self, name):
        return os.path.exists(self.path("weights", f"{name}.pew"))


# ---------------------------------------------------------------------------
# Stage 1: targets
# ---------------------------------------------------------------------------

def stage_targets(art: Artifacts):
    params = {}
    for name, cfg in TARGETS.items():
        template = init_target(jax.random.PRNGKey(0), cfg)
        if art.has_weights(name):
            print(f"[targets] {name}: cached")
            params[name] = art.load_params(name, template)
        else:
            steps = 60 if FAST else 240
            t0 = time.time()
            p, hist = pretrain_target(cfg, steps=steps, batch=8, seq_len=96,
                                      verbose=False)
            print(f"[targets] {name}: trained {steps} steps "
                  f"({time.time()-t0:.0f}s, loss {hist[-1]['loss']:.3f})")
            art.save_params(name, p)
            with open(art.path("logs", f"{name}.json"), "w") as f:
                json.dump(hist, f)
            params[name] = p
        order = [n for n, _ in flatten_named(params[name])[0]]
        art.manifest["targets"][name] = {
            **config_dict(cfg),
            "feature_layers": cfg.feature_layers,
            "feature_dim": cfg.feature_dim,
            "head_dim": cfg.head_dim,
            "weights": f"weights/{name}.pew",
            "param_order": order,
        }
    return params


# ---------------------------------------------------------------------------
# Stage 2: drafters
# ---------------------------------------------------------------------------

def stage_drafters(art: Artifacts, target_params):
    out = {}
    jobs = all_drafters()
    for dcfg in jobs:
        tcfg = TARGETS[dcfg.target]
        template = init_drafter(jax.random.PRNGKey(0), dcfg, tcfg,
                                target_embed=target_params[dcfg.target]["embed"])
        names = [dcfg.name]
        snap_steps = ()
        if dcfg.name == "target-m-pe4":
            snap_steps = tuple(EPOCH_SNAPSHOTS)  # Table 7 epoch ablation
            names += [f"target-m-pe4-{lbl}" for lbl in EPOCH_SNAPSHOTS.values()]
        if all(art.has_weights(n) for n in names):
            print(f"[drafters] {dcfg.name}: cached")
            out[dcfg.name] = art.load_params(dcfg.name, template)
            for n in names[1:]:
                out[n] = art.load_params(n, template)
        else:
            tc = drafter_train_config(dcfg)
            if FAST:
                tc.steps = max(10, tc.steps // 4)
                snap_steps = tuple(max(2, s // 4) for s in snap_steps)
            t0 = time.time()
            p, log, snaps = train_drafter(
                target_params[dcfg.target], tcfg, dcfg, tc,
                snapshot_steps=snap_steps, verbose=False)
            print(f"[drafters] {dcfg.name}: {tc.steps} steps "
                  f"({time.time()-t0:.0f}s, ntp {log['ntp_acc'][-1]:.3f} "
                  f"mtp {log['mtp_acc'][-1]:.3f})")
            art.save_params(dcfg.name, p)
            with open(art.path("logs", f"{dcfg.name}.json"), "w") as f:
                json.dump(log, f)
            out[dcfg.name] = p
            if snap_steps:
                labels = list(EPOCH_SNAPSHOTS.values())
                for (step, sp), lbl in zip(sorted(snaps.items()), labels):
                    sname = f"target-m-pe4-{lbl}"
                    art.save_params(sname, sp)
                    out[sname] = sp
        order = [n for n, _ in flatten_named(out[dcfg.name])[0]]
        tc = drafter_train_config(dcfg)
        art.manifest["drafters"][dcfg.name] = {
            **config_dict(dcfg),
            "weights": f"weights/{dcfg.name}.pew",
            "param_order": order,
            # per-drafter capability record: which speculation modes this
            # drafter's executables support (the Rust policy registry's
            # gate for per-request SpecPolicy validation)
            "modes": drafter_modes(dcfg),
            "train": {"seq_len": tc.seq_len, "k_train": tc.k_train,
                      "cod_ratio": tc.cod_ratio, "segments": tc.segments,
                      "mask_mode": tc.mask_mode, "steps": tc.steps},
        }
        if os.path.exists(art.path("logs", f"{dcfg.name}.json")):
            with open(art.path("logs", f"{dcfg.name}.json")) as f:
                art.manifest["training_logs"][dcfg.name] = json.load(f)
        if dcfg.name == "target-m-pe4":
            for lbl in EPOCH_SNAPSHOTS.values():
                sname = f"target-m-pe4-{lbl}"
                art.manifest["drafters"][sname] = {
                    **config_dict(dcfg), "name": sname,
                    "weights": f"weights/{sname}.pew",
                    "param_order": order,
                    "modes": drafter_modes(dcfg),
                }
    return out


# ---------------------------------------------------------------------------
# Stage 3: lower executables
# ---------------------------------------------------------------------------

def _maybe_lower(art, name, fn, args, kind, meta, outputs_meta):
    path = art.path("hlo", f"{name}.hlo.txt")
    if not os.path.exists(path):
        t0 = time.time()
        size = lower_to_file(fn, args, path)
        print(f"[hlo] {name}: {size/1e3:.0f} kB ({time.time()-t0:.1f}s)")
    art.manifest["executables"].append({
        "name": name, "path": f"hlo/{name}.hlo.txt", "kind": kind, **meta,
        "outputs": outputs_meta,
    })


def stage_lower(art: Artifacts, target_params, drafter_params):
    # --- target executables ------------------------------------------------
    for tname, tcfg in TARGETS.items():
        tp = target_params[tname]
        pspec = spec_of(tp)
        for b in BATCH_SIZES:
            toks = jax.ShapeDtypeStruct((b, PROMPT_PAD), jnp.int32)
            plen = jax.ShapeDtypeStruct((b,), jnp.int32)
            kv = jax.ShapeDtypeStruct(
                (tcfg.n_layers, 2, b, S_MAX, tcfg.n_heads, tcfg.head_dim),
                jnp.float32)
            _maybe_lower(
                art, f"{tname}-prefill-b{b}",
                lambda p, t, l, c, _cfg=tcfg: prefill(p, _cfg, t, l, c),
                (pspec, toks, plen, kv), "prefill",
                {"model": tname, "batch": b},
                [{"name": "last_logits"}, {"name": "feats"}, {"name": "kv"}])
            if b == 1:
                # prefix-cache tail prefill: batch-1 only (admission is
                # per-request), token operand is the left-aligned unique
                # tail, `start` the cached-prefix length. Argument order
                # after the params matches ModelRuntime::prefill_cached:
                # tokens, prompt_len, start, kv.
                tail = jax.ShapeDtypeStruct((1, PREFIX_TAIL_PAD), jnp.int32)
                start = jax.ShapeDtypeStruct((1,), jnp.int32)
                _maybe_lower(
                    art, f"{tname}-prefill-cached-b1",
                    lambda p, t, l, s, c, _cfg=tcfg: prefill_cached(
                        p, _cfg, t, l, s, c),
                    (pspec, tail, plen, start, kv), "prefill-cached",
                    {"model": tname, "batch": 1,
                     "tail_pad": PREFIX_TAIL_PAD},
                    [{"name": "last_logits"}, {"name": "feats"},
                     {"name": "kv"}])
            # paged twin shapes: block pool + per-slot block table (the
            # engine passes the table as a runtime input each step). Argument
            # order after the params must match ModelRuntime::verify_paged:
            # chunk, cache_len, block_table, pool.
            table = jax.ShapeDtypeStruct((b, kv_blocks_per_slot()), jnp.int32)
            pool = jax.ShapeDtypeStruct(
                (tcfg.n_layers, 2, num_kv_blocks(b), KV_BLOCK_SIZE,
                 tcfg.n_heads, tcfg.head_dim), jnp.float32)
            for k in SPEC_DEPTHS:
                chunk = jax.ShapeDtypeStruct((b, k + 1), jnp.int32)
                clen = jax.ShapeDtypeStruct((b,), jnp.int32)
                _maybe_lower(
                    art, f"{tname}-verify-b{b}-k{k}",
                    lambda p, c, l, cache, _cfg=tcfg: verify(p, _cfg, c, l, cache),
                    (pspec, chunk, clen, kv), "verify",
                    {"model": tname, "batch": b, "k": k},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
                _vp = verify_paged if PAGED_GATHER else verify_paged_inplace
                _maybe_lower(
                    art, f"{tname}-verify-paged-b{b}-k{k}",
                    lambda p, c, l, t, pl, _cfg=tcfg, _fn=_vp: _fn(
                        p, _cfg, c, l, t, pl),
                    (pspec, chunk, clen, table, pool), "verify-paged",
                    {"model": tname, "batch": b, "k": k,
                     "block_size": KV_BLOCK_SIZE, "num_blocks": num_kv_blocks(b)},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
            # device accepted-path commit: gather/scatter pool rows per the
            # uploaded [COMMIT_PLAN_ROWS, 4] plan (physical src/dst block+
            # offset rows; padding rows are inert null self-copies). No
            # weights — args are exactly (plan, pool), single "kv" output.
            # Argument order matches ModelRuntime::commit_path_paged.
            plan = jax.ShapeDtypeStruct((COMMIT_PLAN_ROWS, 4), jnp.int32)
            _maybe_lower(
                art, f"{tname}-commit-path-paged-b{b}",
                lambda pln, pl: commit_path_paged(pln, pl),
                (plan, pool), "commit-path-paged",
                {"model": tname, "batch": b, "block_size": KV_BLOCK_SIZE,
                 "num_blocks": num_kv_blocks(b),
                 "plan_rows": COMMIT_PLAN_ROWS},
                [{"name": "kv"}])

    # --- drafter executables -----------------------------------------------
    # every serving drafter (pe2 included — the multi-drafter engine serves
    # it next to pe4/ar from one batch) gets the full chain grid
    serving = {d.name for d in serving_drafters()}
    for dname, dmeta in art.manifest["drafters"].items():
        dcfg = DrafterConfig(**{k: v for k, v in dmeta.items()
                                if k in DrafterConfig.__dataclass_fields__})
        tcfg = TARGETS[dcfg.target]
        dp = drafter_params[dname]
        dspec = spec_of(dp)
        fn = draft_ar if dcfg.kind == "ar" else draft_pe
        grids = ([(b, k) for b in BATCH_SIZES for k in SPEC_DEPTHS]
                 if dname in serving else [(1, DEFAULT_K)])
        for b, k in grids:
            ct = jax.ShapeDtypeStruct((b, CTX_WINDOW), jnp.int32)
            cf = jax.ShapeDtypeStruct((b, CTX_WINDOW, tcfg.feature_dim),
                                      jnp.float32)
            p0 = jax.ShapeDtypeStruct((b,), jnp.int32)
            _maybe_lower(
                art, f"{dname}-draft-b{b}-k{k}",
                lambda p, c, f, q, _cfg=dcfg, _k=k, _fn=fn: _fn(
                    p, _cfg, c, f, q, _k, attn_impl=KERNEL),
                (dspec, ct, cf, p0), "draft",
                {"model": dcfg.target, "drafter": dname, "batch": b, "k": k},
                [{"name": "tokens"}])

    # --- tree executables (static topologies; target-m workhorse only) -----
    # The Rust engine passes the cross-node ancestor mask as a RUNTIME input
    # (it precomputes it once per topology — masking/tree.rs); per-slot depth
    # offsets are static and baked into the HLO. Argument order after the
    # params must match ModelRuntime::verify_tree: chunk, cache_len,
    # tree_mask, kv.
    for topo in TREE_TOPOLOGIES:
        tid = tree_topology_id(topo)
        n_nodes = sum(topo)
        depths = tuple(tree_depths(topo))
        for tname in TREE_TARGETS:
            tcfg = TARGETS[tname]
            pspec = spec_of(target_params[tname])
            for b in BATCH_SIZES:
                chunk = jax.ShapeDtypeStruct((b, n_nodes + 1), jnp.int32)
                clen = jax.ShapeDtypeStruct((b,), jnp.int32)
                tmask = jax.ShapeDtypeStruct((n_nodes + 1, n_nodes + 1),
                                             jnp.int32)
                kv = jax.ShapeDtypeStruct(
                    (tcfg.n_layers, 2, b, S_MAX, tcfg.n_heads, tcfg.head_dim),
                    jnp.float32)
                _maybe_lower(
                    art, f"{tname}-verify-tree-{tid}-b{b}",
                    lambda p, c, l, m, cache, _cfg=tcfg, _d=depths: verify_tree(
                        p, _cfg, c, l, cache, m, _d),
                    (pspec, chunk, clen, tmask, kv), "verify-tree",
                    {"model": tname, "batch": b, "k": n_nodes, "topology": tid},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
                # paged twin — arg order after the mask matches
                # ModelRuntime::verify_tree_paged: chunk, cache_len,
                # tree_mask, block_table, pool.
                table = jax.ShapeDtypeStruct((b, kv_blocks_per_slot()),
                                             jnp.int32)
                pool = jax.ShapeDtypeStruct(
                    (tcfg.n_layers, 2, num_kv_blocks(b), KV_BLOCK_SIZE,
                     tcfg.n_heads, tcfg.head_dim), jnp.float32)
                _vtp = (verify_tree_paged if PAGED_GATHER
                        else verify_tree_paged_inplace)
                _maybe_lower(
                    art, f"{tname}-verify-tree-paged-{tid}-b{b}",
                    lambda p, c, l, m, t, pl, _cfg=tcfg, _d=depths, _fn=_vtp:
                        _fn(p, _cfg, c, l, t, pl, m, _d),
                    (pspec, chunk, clen, tmask, table, pool),
                    "verify-tree-paged",
                    {"model": tname, "batch": b, "k": n_nodes, "topology": tid,
                     "block_size": KV_BLOCK_SIZE, "num_blocks": num_kv_blocks(b)},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
        for dname in tree_drafters():
            dmeta = art.manifest["drafters"][dname]
            dcfg = DrafterConfig(**{k: v for k, v in dmeta.items()
                                    if k in DrafterConfig.__dataclass_fields__})
            tcfg = TARGETS[dcfg.target]
            dspec = spec_of(drafter_params[dname])
            for b in BATCH_SIZES:
                ct = jax.ShapeDtypeStruct((b, CTX_WINDOW), jnp.int32)
                cf = jax.ShapeDtypeStruct((b, CTX_WINDOW, tcfg.feature_dim),
                                          jnp.float32)
                p0 = jax.ShapeDtypeStruct((b,), jnp.int32)
                _maybe_lower(
                    art, f"{dname}-draft-tree-{tid}-b{b}",
                    lambda p, c, f, q, _cfg=dcfg, _w=tuple(topo): draft_pe_tree(
                        p, _cfg, c, f, q, _w, attn_impl=KERNEL),
                    (dspec, ct, cf, p0), "draft-tree",
                    {"model": dcfg.target, "drafter": dname, "batch": b,
                     "k": n_nodes, "topology": tid},
                    [{"name": "tokens"}])

    # --- dynamic-tree (max-shape envelope) executables ----------------------
    # One lowering per ENVELOPE: the cross-node mask ([B, N+1, N+1]) and the
    # per-slot RoPE depth offsets ([B, N+1]) are per-batch RUNTIME inputs, so
    # the Rust engine activates a different confidence-selected, compacted
    # node subset per slot per step (rust/src/masking/dynamic.rs). The scored
    # drafter returns (tokens, joint logp) — the selection signal. Argument
    # order after the params must match ModelRuntime::verify_tree_dyn
    # (chunk, cache_len, tree_mask, depth_offsets, kv) and its paged twin
    # (.., block_table, pool).
    for topo in TREE_DYN_ENVELOPES:
        tid = tree_topology_id(topo)
        n_nodes = sum(topo)
        for tname in TREE_TARGETS:
            tcfg = TARGETS[tname]
            pspec = spec_of(target_params[tname])
            for b in BATCH_SIZES:
                chunk = jax.ShapeDtypeStruct((b, n_nodes + 1), jnp.int32)
                clen = jax.ShapeDtypeStruct((b,), jnp.int32)
                tmask = jax.ShapeDtypeStruct((b, n_nodes + 1, n_nodes + 1),
                                             jnp.int32)
                doffs = jax.ShapeDtypeStruct((b, n_nodes + 1), jnp.int32)
                kv = jax.ShapeDtypeStruct(
                    (tcfg.n_layers, 2, b, S_MAX, tcfg.n_heads, tcfg.head_dim),
                    jnp.float32)
                _maybe_lower(
                    art, f"{tname}-verify-tree-dyn-{tid}-b{b}",
                    lambda p, c, l, m, o, cache, _cfg=tcfg: verify_tree_dyn(
                        p, _cfg, c, l, cache, m, o),
                    (pspec, chunk, clen, tmask, doffs, kv), "verify-tree-dyn",
                    {"model": tname, "batch": b, "k": n_nodes, "topology": tid},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
                table = jax.ShapeDtypeStruct((b, kv_blocks_per_slot()),
                                             jnp.int32)
                pool = jax.ShapeDtypeStruct(
                    (tcfg.n_layers, 2, num_kv_blocks(b), KV_BLOCK_SIZE,
                     tcfg.n_heads, tcfg.head_dim), jnp.float32)
                _vdp = (verify_tree_dyn_paged if PAGED_GATHER
                        else verify_tree_dyn_paged_inplace)
                _maybe_lower(
                    art, f"{tname}-verify-tree-dyn-paged-{tid}-b{b}",
                    lambda p, c, l, m, o, t, pl, _cfg=tcfg, _fn=_vdp:
                        _fn(p, _cfg, c, l, t, pl, m, o),
                    (pspec, chunk, clen, tmask, doffs, table, pool),
                    "verify-tree-dyn-paged",
                    {"model": tname, "batch": b, "k": n_nodes, "topology": tid,
                     "block_size": KV_BLOCK_SIZE, "num_blocks": num_kv_blocks(b)},
                    [{"name": "logits"}, {"name": "feats"}, {"name": "kv"}])
        for dname in tree_drafters():
            dmeta = art.manifest["drafters"][dname]
            dcfg = DrafterConfig(**{k: v for k, v in dmeta.items()
                                    if k in DrafterConfig.__dataclass_fields__})
            tcfg = TARGETS[dcfg.target]
            dspec = spec_of(drafter_params[dname])
            for b in BATCH_SIZES:
                ct = jax.ShapeDtypeStruct((b, CTX_WINDOW), jnp.int32)
                cf = jax.ShapeDtypeStruct((b, CTX_WINDOW, tcfg.feature_dim),
                                          jnp.float32)
                p0 = jax.ShapeDtypeStruct((b,), jnp.int32)
                _maybe_lower(
                    art, f"{dname}-draft-tree-logp-{tid}-b{b}",
                    lambda p, c, f, q, _cfg=dcfg, _w=tuple(topo): draft_pe_tree(
                        p, _cfg, c, f, q, _w, attn_impl=KERNEL,
                        return_logp=True),
                    (dspec, ct, cf, p0), "draft-tree-logp",
                    {"model": dcfg.target, "drafter": dname, "batch": b,
                     "k": n_nodes, "topology": tid},
                    [{"name": "tokens"}, {"name": "logp"}])

    # --- runtime selftest (load_hlo-style smoke executable) -----------------
    def smoke(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    _maybe_lower(art, "selftest", smoke, (s, s), "selftest", {}, [{"name": "out"}])


# ---------------------------------------------------------------------------
# Stage 4: eval prompts + regime tables for the Rust mirror
# ---------------------------------------------------------------------------

def stage_data(art: Artifacts):
    for regime in DATASETS:
        r = data_mod.PhraseRegime(regime)
        art.manifest["regimes"][regime] = r.export_tables()
        prompts = data_mod.eval_prompts(regime, 64, 24, seed=42)
        path = art.path("eval", f"{regime}.json")
        with open(path, "w") as f:
            json.dump(prompts.tolist(), f)
        art.manifest["eval_prompts"][regime] = f"eval/{regime}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="manifest output path")
    ap.add_argument("--root", default=None, help="artifacts root dir")
    args = ap.parse_args()
    root = args.root or os.path.join(os.path.dirname(__file__), "..", "..",
                                     "artifacts")
    root = os.path.abspath(root)
    art = Artifacts(root)
    t0 = time.time()
    tparams = stage_targets(art)
    dparams = stage_drafters(art, tparams)
    stage_lower(art, tparams, dparams)
    stage_data(art)
    out = args.out or art.path("manifest.json")
    with open(out, "w") as f:
        json.dump(art.manifest, f, indent=1)
    print(f"[aot] manifest -> {out} ({time.time()-t0:.0f}s total)")


if __name__ == "__main__":
    main()
