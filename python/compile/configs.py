"""Model / drafter / training configuration registry for the P-EAGLE reproduction.

The paper's three production targets (GPT-OSS 120B, GPT-OSS 20B,
Qwen3-Coder 30B) are substituted by three trained mini LLaMA-style targets of
distinct sizes (see DESIGN.md §Hardware-Adaptation). All scale-free knobs of
the paper — K_train=8, COD ratio r=0.8, speculation depths {3,5,7},
concurrency {2,4}, layer-count ablation {1,2,4} — are kept unchanged.
"""

from dataclasses import dataclass, field, asdict
from typing import List, Optional

# ---------------------------------------------------------------------------
# Global token conventions (shared with rust/src/workload/corpus.rs)
# ---------------------------------------------------------------------------
VOCAB = 256
PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
MASK_ID = 3          # the paper's "pre-defined unused token ID" for MTP slots
FIRST_CONTENT_ID = 4

# Serving shape constants (fixed AOT shapes; see DESIGN.md)
S_MAX = 256          # KV cache capacity per slot
PROMPT_PAD = 64      # prefill executable prompt width
CTX_WINDOW = 8       # drafter rolling (token, feature) context width
MAX_NEW_TOKENS = 160

# Paged KV cache (block-table indirection, vLLM-style). The physical cache of
# the paged executables is a block pool [L, 2, NUM_BLOCKS, KV_BLOCK_SIZE, H,
# Dh]; each engine slot owns a table of pool block ids covering its logical
# positions. Block 0 is the reserved null block: inactive rows and unused
# table entries point at it, so their gather reads and scatter write-backs
# are inert. Must divide S_MAX, and must match the Rust engine's configured
# block size (manifest `kv_block_size`).
KV_BLOCK_SIZE = 16
assert S_MAX % KV_BLOCK_SIZE == 0

# Prefix-cache tail prefill width (the `prefill-cached` executables): a
# cache-hit admission prefills only the prompt's unique tail, left-aligned
# into a [1, PREFIX_TAIL_PAD] token operand at a runtime `start` offset.
# Must cover CTX_WINDOW (the drafter needs features for the last CTX_WINDOW
# prompt positions, so the engine computes from min(cached_len, plen - ctx))
# and stay within PROMPT_PAD (a tail as wide as the full prefill would never
# pay); hits with longer unique tails fall back to the full prefill
# executable while still sharing prefix blocks. The widest scatter,
# start = PROMPT_PAD - 1 plus PREFIX_TAIL_PAD tail slots, must stay inside
# the S_MAX cache window.
PREFIX_TAIL_PAD = 32
assert CTX_WINDOW <= PREFIX_TAIL_PAD <= PROMPT_PAD
assert PROMPT_PAD - 1 + PREFIX_TAIL_PAD <= S_MAX


# On-device accepted-path commit (the `commit-path-paged` executables): the
# engine uploads a [COMMIT_PLAN_ROWS, 4] int32 plan of physical
# (src_block, src_off, dst_block, dst_off) position copies per step, padded
# with inert (0, 0, 0, 0) null-block self-copies. One slot's accepted path
# contributes at most max(SPEC_DEPTHS) copies (the deepest accepted path of
# the deepest lowered policy), and at most `batch` slots commit per step, so
# 32 covers every lowered (batch <= 4, depth <= 7) shape with headroom; the
# engine falls back to the host copy path if a step ever plans more.
COMMIT_PLAN_ROWS = 32


def kv_blocks_per_slot() -> int:
    """Block-table width per engine slot (covers the full S_MAX window)."""
    return S_MAX // KV_BLOCK_SIZE


def num_kv_blocks(batch: int) -> int:
    """Physical pool size lowered for a batch-`batch` paged executable:
    full per-slot provisioning plus the reserved null block 0 (the Rust
    engine may budget FEWER logical blocks for preemption-pressure tests,
    but never more than the lowered pool holds)."""
    return batch * kv_blocks_per_slot() + 1


@dataclass
class TargetConfig:
    """LLaMA-style decoder-only target model (the paper's 'target model')."""

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    vocab: int = VOCAB
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        return 4 * self.d_model

    @property
    def feature_layers(self) -> List[int]:
        """EAGLE-3 feature taps: hidden states after layers 2, L/2, L-1.

        (0-based layer indices; for shallow models the low tap drops to 1 so
        the three taps stay distinct.)
        """
        lo = 2 if self.n_layers > 4 else 1
        mid = self.n_layers // 2
        hi = self.n_layers - 1
        return [lo, mid, hi]

    @property
    def feature_dim(self) -> int:
        return 3 * self.d_model


@dataclass
class DrafterConfig:
    """EAGLE-style drafter (AR baseline, P-EAGLE, or ParallelSpec variant)."""

    name: str
    target: str                      # TargetConfig.name this drafter serves
    kind: str = "peagle"             # peagle | ar | parallelspec
    n_layers: int = 4
    d_model: int = 48
    n_heads: int = 4
    # P-EAGLE hidden-state design (paper §4.1 / Table 3):
    #   shared          -> learnable h_shared (paper's recommended baseline)
    #   depth           -> h_shared + e_depth[g]
    #   ntp_depth       -> h_shared + proj(h_ntp) + e_depth[g]
    #   ntp             -> h_shared + proj(h_ntp)
    #   reg_ntp         -> h_shared + alpha * dropout(proj(h_ntp))
    #   none            -> zeros (ParallelSpec-style: mask token only)
    hidden_mode: str = "shared"
    freeze_embeddings: bool = False  # paper §4.3: False (+5%) is the recipe
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        return 4 * self.d_model


@dataclass
class TrainConfig:
    """Drafter training configuration (paper §3 + Appendix A, scaled)."""

    seq_len: int = 96                # maps to the paper's 8192 (single-core budget)
    k_train: int = 8                 # parallel prediction groups (paper: 8)
    cod_ratio: float = 0.8           # COD geometric retention rate (paper: 0.8)
    segments: int = 1                # within-sequence gradient accumulation (§3.2)
    mask_mode: str = "amortized"     # amortized (ours) | pard (per-example O((nK)^2))
    steps: int = 320
    batch: int = 3                   # global batch (micro-batch stacking in train.py)
    micro_batch: int = 1
    lr: float = 3e-3                 # scaled-up from the paper's 1e-4 (tiny model)
    warmup_ratio: float = 0.0025     # paper: 0.0025
    ttt_passes: int = 2              # EAGLE-3 Training-Time-Test passes (AR only)
    seed: int = 0


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

# Paper model -> mini analog (names used throughout benches & EXPERIMENTS.md)
TARGETS = {
    # GPT-OSS 120B analog: deepest/widest
    "target-l": TargetConfig(name="target-l", d_model=128, n_layers=8, n_heads=4),
    # GPT-OSS 20B analog: shallow (paper's ablation workhorse)
    "target-m": TargetConfig(name="target-m", d_model=96, n_layers=4, n_heads=4),
    # Qwen3-Coder 30B analog: mid-depth
    "target-s": TargetConfig(name="target-s", d_model=112, n_layers=6, n_heads=4),
}

PAPER_NAME = {
    "target-l": "GPT-OSS 120B",
    "target-m": "GPT-OSS 20B",
    "target-s": "Qwen3-Coder 30B",
}

# Evaluation regimes (analogs of the paper's OOD benchmarks)
DATASETS = ["humaneval", "mtbench", "gsm8k"]

# Serving executable shape grid
BATCH_SIZES = [1, 2, 4]
SPEC_DEPTHS = [3, 5, 7]
DEFAULT_K = 5

# every slot's accepted path plans at most max-depth copies, and at most
# `batch` slots commit per step (see COMMIT_PLAN_ROWS above)
assert max(BATCH_SIZES) * max(SPEC_DEPTHS) <= COMMIT_PLAN_ROWS

# Static draft-tree width profiles lowered as tree executables (aot.py):
# widths[d] nodes at depth d+1, level-major ids — see masks.tree_parents and
# rust/src/masking/tree.rs. The all-ones profile is the chain-as-degenerate-
# tree parity case; the branching profile is the serving default of
# `bench-otps --tree`. Tree/dyn executables are lowered for the target-m
# workhorse and EVERY tree-capable serving drafter of it (pe4 + pe2 — each
# topology × drafter × batch costs a lowering, so other targets keep chain
# only); the Rust engine can then mix drafters per request inside one batch.
TREE_TOPOLOGIES = [(1,) * DEFAULT_K, (3, 2, 1, 1, 1)]
TREE_TARGETS = ["target-m"]


def drafter_modes(d: "DrafterConfig") -> list:
    """Speculation modes a drafter's executables support, recorded in the
    manifest per drafter (the Rust policy registry's capability gate —
    `SpecPolicy::mode_name` values). The AR scan drafts chains only
    (`draft_ar` has no single-pass tree form); parallel drafters
    (`draft_pe` / `draft_pe_tree`) draft every shape."""
    return ["chain"] if d.kind == "ar" else ["chain", "tree", "dyn"]


def tree_drafters() -> list:
    """Serving drafters whose tree/dyn executables are lowered: every
    tree-capable serving drafter of the TREE_TARGETS workhorses."""
    return [d.name for d in serving_drafters()
            if d.target in TREE_TARGETS and "tree" in drafter_modes(d)]

# Dynamic-tree max-shape envelopes (aot.py lowers a `verify-tree-dyn` /
# `verify-tree-dyn-paged` / `draft-tree-logp` triple per envelope): the
# cross-node mask AND the per-slot RoPE depth offsets are per-batch RUNTIME
# inputs, so the Rust engine can activate a different confidence-selected
# node subset per slot per step (rust/src/masking/dynamic.rs). The static
# topologies are included so the degenerate case (budget == envelope nodes)
# can be parity-tested against the static executables; the wide serving
# envelope gives confidence selection room that no static profile commits
# to. DEFAULT_TREE_BUDGET matches the static serving tree's node count so
# default comparisons spend an equal verified-node budget.
TREE_DYN_ENVELOPE = (4, 4, 2, 2, 1)
TREE_DYN_ENVELOPES = TREE_TOPOLOGIES + [TREE_DYN_ENVELOPE]
DEFAULT_TREE_BUDGET = sum(TREE_TOPOLOGIES[1])
assert DEFAULT_TREE_BUDGET <= sum(TREE_DYN_ENVELOPE)


def serving_drafters():
    """The drafters used in Tables 9/10/11: AR EAGLE-3 + P-EAGLE 4L (+2L)."""
    out = []
    for t in TARGETS:
        out.append(DrafterConfig(name=f"{t}-ar", target=t, kind="ar", n_layers=1))
        out.append(DrafterConfig(name=f"{t}-pe4", target=t, kind="peagle", n_layers=4))
        out.append(DrafterConfig(name=f"{t}-pe2", target=t, kind="peagle", n_layers=2))
    return out


def ablation_drafters():
    """Ablation variants (Tables 3-8) — all on target-m (paper uses GPT-OSS
    20B for Table 3 and LLaMA 3.1 8B for Tables 4-8; we substitute target-m
    for both, recorded in DESIGN.md)."""
    t = "target-m"
    out = [
        # Table 3: hidden-state designs (4-layer, per the paper; baseline is
        # the serving pe4)
        DrafterConfig(name=f"{t}-hs-depth", target=t, n_layers=4, hidden_mode="depth"),
        DrafterConfig(name=f"{t}-hs-ntp-depth", target=t, n_layers=4, hidden_mode="ntp_depth"),
        DrafterConfig(name=f"{t}-hs-ntp", target=t, n_layers=4, hidden_mode="ntp"),
        DrafterConfig(name=f"{t}-hs-reg", target=t, n_layers=4, hidden_mode="reg_ntp"),
        # Table 4: layer count (1L; 2L and 4L come from serving_drafters).
        # The 1L model is also the Table 5/6/8 baseline (paper §4 trains
        # those ablations with a single decoder layer).
        DrafterConfig(name=f"{t}-pe1", target=t, kind="peagle", n_layers=1),
        # Table 5: frozen embeddings (1L)
        DrafterConfig(name=f"{t}-frozen", target=t, n_layers=1, freeze_embeddings=True),
        # Table 6: K_train=5 (baseline pe1 trains with K_train=8)
        DrafterConfig(name=f"{t}-ktr5", target=t, n_layers=1),
        # Table 8: shorter training sequences (n=48 vs baseline 96)
        DrafterConfig(name=f"{t}-seq48", target=t, n_layers=1),
    ]
    return out


def table1_drafters():
    """Table 1 context-length scaling variants (target-l, the 120B analog)."""
    t = "target-l"
    out = []
    for n in [64, 128, 256, 512]:  # maps to paper {1K, 4K, 8K, 20K}
        out.append(DrafterConfig(name=f"{t}-pe-n{n}", target=t, kind="peagle", n_layers=4))
    for n in [64, 128]:
        out.append(DrafterConfig(name=f"{t}-ps-n{n}", target=t, kind="parallelspec",
                                 n_layers=1, hidden_mode="none"))
    out.append(DrafterConfig(name=f"{t}-pard-n64", target=t, kind="peagle", n_layers=4))
    return out


TABLE1_CONTEXTS = {64: "1K", 128: "4K", 256: "8K", 512: "20K"}

# Table 7 ("epochs 20/40/60") snapshots, taken from the target-m pe4 run.
# (Step ratio 1:2:4 vs the paper's 1:2:3 — the 320-step snapshot doubles as
# the fair same-budget baseline for the Table 3 hidden-state ablation.)
EPOCH_SNAPSHOTS = {160: "20ep", 320: "40ep", 640: "60ep"}


def drafter_train_config(d: DrafterConfig) -> TrainConfig:
    """Per-variant training configuration (fixed token budget across context
    lengths, mirroring the paper's fixed-epoch training)."""
    tc = TrainConfig()
    name = d.name
    if "-n" in name and name.rsplit("-n", 1)[1].isdigit():
        n = int(name.rsplit("-n", 1)[1])
        tc.seq_len = n
        tc.segments = max(1, n // 128)
        tc.steps = {64: 320, 128: 240, 256: 120, 512: 56}.get(n, 320)
    if "pard" in name:
        tc.mask_mode = "pard"
        tc.steps = 150   # per-example mask construction dominates (Table 2)
    if "ktr5" in name:
        tc.k_train = 5
    if "seq48" in name:
        tc.seq_len = 48
    if d.kind == "ar":
        tc.steps = 300   # 2 TTT passes/step; strong baseline (paper note)
    if d.kind == "peagle" and d.n_layers == 4 and name.endswith("-pe4"):
        # serving P-EAGLE drafters get the extended-duration recipe the
        # paper's §4.5 calls for (P-EAGLE is the harder learning problem)
        tc.steps = 640
    return tc


def all_drafters():
    return serving_drafters() + ablation_drafters() + table1_drafters()


def get_drafter(name: str) -> DrafterConfig:
    for d in all_drafters():
        if d.name == name:
            return d
    raise KeyError(name)


def config_dict(cfg) -> dict:
    return asdict(cfg)
