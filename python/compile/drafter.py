"""L2 drafter models: AR EAGLE-3 baseline, P-EAGLE, and the ParallelSpec
variant — shared row-wise formulation for training and serving.

Row convention (fixed across training, serving, and the Rust engine):
a drafter row for absolute token position t carries input pair
(token_t, target-feature at t-1) and predicts token_{t+1}; its RoPE position
is t-1 ("row space" = token index - 1). Depth-d MTP rows at row position p
carry (MASK embedding, h_variant) anchored at the depth-0 row p-d.

Inference windows: `draft_pe` runs ONE forward over
[C context rows | K-1 MTP slots] (chain drafting makes the mask plain causal
— DESIGN.md); `draft_ar` runs K sequential window passes inside a
`lax.fori_loop`, so the K× sequential drafter cost is physically present in
the lowered HLO the Rust engine executes.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .common import (
    NEG_INF,
    cross_entropy,
    dense_init,
    embed_init,
    mask_to_bias,
    rms_norm,
    init_block,
    apply_rope,
    sdpa,
    swiglu,
)
from .configs import CTX_WINDOW, MASK_ID, DrafterConfig, TargetConfig

K_MAX = 8  # depth-embedding table size (>= K_train)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_drafter(key, cfg: DrafterConfig, tcfg: TargetConfig, target_embed=None):
    ks = jax.random.split(key, cfg.n_layers + 8)
    dd = cfg.d_model
    if target_embed is not None:
        # paper §4.3: token embeddings inherited from the target model
        embed = jnp.asarray(target_embed[:, :dd])
    else:
        embed = embed_init(ks[0], tcfg.vocab, dd)
    params = {
        "embed": embed,
        "proj_feat": dense_init(ks[1], tcfg.feature_dim, dd),
        "fuse": dense_init(ks[2], 2 * dd, dd),
        "blocks": [
            init_block(ks[3 + i], dd, cfg.n_heads, cfg.ffn_dim)
            for i in range(cfg.n_layers)
        ],
        "ln_f": jnp.ones((dd,), jnp.float32),
        "lm_head": dense_init(ks[-3], dd, tcfg.vocab),
        # P-EAGLE learnables (paper §2)
        "h_shared": jax.random.normal(ks[-2], (dd,), jnp.float32) * 0.02,
    }
    if cfg.hidden_mode in ("depth", "ntp_depth"):
        params["e_depth"] = jax.random.normal(ks[-1], (K_MAX, dd), jnp.float32) * 0.02
    if cfg.hidden_mode in ("ntp", "ntp_depth", "reg_ntp"):
        params["proj_ntp"] = dense_init(ks[-1], tcfg.feature_dim, dd)
    if cfg.hidden_mode == "reg_ntp":
        params["alpha"] = jnp.asarray(0.1, jnp.float32)  # paper App. B.2 init
    return params


def mtp_hidden(params, cfg: DrafterConfig, depth, feat_anchor, dropout_key=None):
    """h_variant for an MTP row (paper §4.1 / Appendix B.2).

    depth: [...] int32 (>=1); feat_anchor: [..., 3dt] target features of the
    anchor NTP position (used by the ntp* variants).
    """
    dd = cfg.d_model
    mode = cfg.hidden_mode
    if mode == "none":  # ParallelSpec: no shared hidden state
        return jnp.zeros(feat_anchor.shape[:-1] + (dd,), jnp.float32)
    h = jnp.broadcast_to(params["h_shared"], feat_anchor.shape[:-1] + (dd,))
    if mode == "shared":
        return h
    if mode in ("depth", "ntp_depth"):
        h = h + params["e_depth"][jnp.clip(depth, 0, K_MAX - 1)]
    if mode in ("ntp", "ntp_depth"):
        h = h + feat_anchor @ params["proj_ntp"]
    if mode == "reg_ntp":
        ctx = feat_anchor @ params["proj_ntp"]
        if dropout_key is not None:  # train-time dropout (rate 0.1)
            keep = jax.random.bernoulli(dropout_key, 0.9, ctx.shape)
            ctx = jnp.where(keep, ctx / 0.9, 0.0)
        h = h + params["alpha"] * ctx
    return h


# ---------------------------------------------------------------------------
# Core row forward
# ---------------------------------------------------------------------------

def drafter_blocks(params, cfg: DrafterConfig, x, positions, bias,
                   attn_impl="jnp"):
    """x: [B,T,dd] fused row inputs -> post-norm hidden [B,T,dd].

    attn_impl: "jnp" (training / oracle) or "pallas" (the L1 fused kernel,
    used in the exported serving drafters)."""
    B, T, dd = x.shape
    H, Dh = cfg.n_heads, cfg.head_dim
    for blk in params["blocks"]:
        h = rms_norm(x, blk["ln1"], cfg.norm_eps)
        q = apply_rope((h @ blk["wq"]).reshape(B, T, H, Dh), positions, cfg.rope_theta)
        k = apply_rope((h @ blk["wk"]).reshape(B, T, H, Dh), positions, cfg.rope_theta)
        v = (h @ blk["wv"]).reshape(B, T, H, Dh)
        qt, kt, vt = (a.transpose(0, 2, 1, 3) for a in (q, k, v))
        if attn_impl == "pallas":
            from .kernels.draft_attention import draft_attention
            a = draft_attention(qt, kt, vt, bias)
        else:
            a = sdpa(qt, kt, vt, bias)
        x = x + a.transpose(0, 2, 1, 3).reshape(B, T, dd) @ blk["wo"]
        h2 = rms_norm(x, blk["ln2"], cfg.norm_eps)
        x = x + swiglu(h2, blk["w_gate"], blk["w_up"], blk["w_down"])
    return rms_norm(x, params["ln_f"], cfg.norm_eps)


def fuse_rows(params, tok_emb, h_in):
    return jnp.concatenate([tok_emb, h_in], axis=-1) @ params["fuse"]


# ---------------------------------------------------------------------------
# Serving: P-EAGLE parallel drafting (single forward pass)
# ---------------------------------------------------------------------------

def _pe_depth_logits(params, cfg: DrafterConfig, ctx_tokens, ctx_feats,
                     row_pos0, k, attn_impl="pallas"):
    """One parallel forward -> per-depth draft logits [B, k, V].

    Row j of the result is the drafter's distribution for the token at depth
    j+1 beyond the last verified token (row 0 comes from the last context
    row, rows 1..k-1 from the MTP slots). Shared by chain drafting
    (`draft_pe` takes the argmax) and tree drafting (`draft_pe_tree` takes
    each level's top-w tokens).
    """
    B, C = ctx_tokens.shape
    T = C + k - 1
    dd = cfg.d_model

    # context rows
    ctx_emb = params["embed"][ctx_tokens]                       # [B,C,dd]
    ctx_h = ctx_feats @ params["proj_feat"]                     # [B,C,dd]
    x_ctx = fuse_rows(params, ctx_emb, ctx_h)

    # MTP slots (depths 1..k-1), all anchored at the last context row
    depths = jnp.arange(1, k, dtype=jnp.int32)                  # [k-1]
    feat_anchor = jnp.broadcast_to(
        ctx_feats[:, -1:, :], (B, k - 1, ctx_feats.shape[-1])
    )
    h_mtp = mtp_hidden(params, cfg, depths[None, :], feat_anchor)
    mask_emb = jnp.broadcast_to(params["embed"][MASK_ID], (B, k - 1, dd))
    x_mtp = fuse_rows(params, mask_emb, h_mtp)

    x = jnp.concatenate([x_ctx, x_mtp], axis=1)                 # [B,T,dd]
    offs = jnp.concatenate([
        jnp.arange(-(C - 1), 1, dtype=jnp.int32),
        jnp.arange(1, k, dtype=jnp.int32),
    ])
    positions = row_pos0[:, None] + offs[None, :]
    bias = mask_to_bias(jnp.tril(jnp.ones((T, T), bool)))[None, None]

    h = drafter_blocks(params, cfg, x, positions, bias, attn_impl)
    return h[:, C - 1:, :] @ params["lm_head"]                  # [B,k,V]


def draft_pe(params, cfg: DrafterConfig, ctx_tokens, ctx_feats, row_pos0, k,
             attn_impl="pallas"):
    """One-pass parallel drafting (the paper's contribution).

    ctx_tokens: [B, C] tokens at consecutive absolute positions ending at the
    last verified token; ctx_feats: [B, C, 3dt] target features at those
    positions minus one; row_pos0: [B] RoPE position of the last context row.
    Returns draft tokens [B, k] int32.
    """
    logits = _pe_depth_logits(params, cfg, ctx_tokens, ctx_feats, row_pos0, k,
                              attn_impl)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def draft_pe_tree(params, cfg: DrafterConfig, ctx_tokens, ctx_feats, row_pos0,
                  widths, attn_impl="pallas", return_logp=False):
    """One-pass parallel TREE drafting over a static width profile.

    `widths` (STATIC python tuple, baked into the HLO) gives the node count
    per depth; the level's nodes take that depth's top-w tokens in rank
    order, so node j of a level is the (j+1)-th most likely continuation at
    that depth. P-EAGLE's MTP slots are anchored at the last context row —
    depth distributions are path-independent — so the whole tree still costs
    ONE drafter forward, the paper's parallel-drafting property extended to
    trees. Returns [B, N] int32 node tokens in level-major order (matching
    rust/src/masking/tree.rs node ids 1..N); tokens within a level are
    distinct by construction.

    With `return_logp` (the `draft-tree-logp` lowering for dynamic-tree
    serving) additionally returns each node's JOINT log-probability
    [B, N] f32: the node's own level log-softmax probability plus its
    parent's joint — i.e. the drafter's log-confidence in the whole root
    path ending at that node, the signal EAGLE-2-style selection ranks by.
    Monotone non-increasing along every path by construction.

    widths == (1,)*k reproduces `draft_pe` exactly (argmax per depth).
    """
    k = len(widths)
    logits = _pe_depth_logits(params, cfg, ctx_tokens, ctx_feats, row_pos0, k,
                              attn_impl)
    levels, level_logps = [], []
    logp = jax.nn.log_softmax(logits, axis=-1) if return_logp else None
    for d, w in enumerate(widths):
        if w == 1:
            idx = jnp.argmax(logits[:, d], axis=-1)[:, None]
        else:
            _, idx = jax.lax.top_k(logits[:, d], w)
        levels.append(idx)
        if return_logp:
            level_logps.append(jnp.take_along_axis(logp[:, d], idx, axis=1))
    tokens = jnp.concatenate(levels, axis=1).astype(jnp.int32)
    if not return_logp:
        return tokens
    # joint[node] = level logp + parent's joint; parents are static
    # (masks.tree_parents), so this is a static unrolled accumulation
    from .masks import tree_parents
    own = jnp.concatenate(level_logps, axis=1)                  # [B, N]
    parents = tree_parents(list(widths))
    joint_cols = []
    for i, p in enumerate(parents, start=1):
        j = own[:, i - 1]
        if p != 0:
            j = j + joint_cols[p - 1]
        joint_cols.append(j)
    joint = jnp.stack(joint_cols, axis=1)                       # [B, N]
    return tokens, joint


# ---------------------------------------------------------------------------
# Serving: AR EAGLE-3 baseline (K sequential passes in-graph)
# ---------------------------------------------------------------------------

def draft_ar(params, cfg: DrafterConfig, ctx_tokens, ctx_feats, row_pos0, k,
             attn_impl="pallas"):
    """Autoregressive drafting: K sequential drafter forward passes.

    Same I/O contract as draft_pe. Step j >= 1 feeds back (draft token j,
    drafter hidden of the previous row) — the EAGLE recurrence. The
    fori_loop keeps the sequential dependency inside the lowered HLO.
    """
    B, C = ctx_tokens.shape
    T = C + k - 1
    dd = cfg.d_model

    ctx_emb = params["embed"][ctx_tokens]
    ctx_h = ctx_feats @ params["proj_feat"]
    x_ctx = fuse_rows(params, ctx_emb, ctx_h)
    x = jnp.concatenate([x_ctx, jnp.zeros((B, k - 1, dd), jnp.float32)], axis=1)

    offs = jnp.concatenate([
        jnp.arange(-(C - 1), 1, dtype=jnp.int32),
        jnp.arange(1, k, dtype=jnp.int32),
    ])
    positions = row_pos0[:, None] + offs[None, :]
    causal = jnp.tril(jnp.ones((T, T), bool))

    def fwd(x_buf, n_valid):
        ok = jnp.arange(T) < n_valid
        bias = mask_to_bias(causal & ok[None, :])[None, None]
        return drafter_blocks(params, cfg, x_buf, positions, bias, attn_impl)

    # pass 1: draft token 1 from the last context row
    h = fwd(x, C)
    t1 = jnp.argmax(h[:, C - 1] @ params["lm_head"], axis=-1).astype(jnp.int32)
    tokens0 = jnp.zeros((B, k), jnp.int32).at[:, 0].set(t1)

    def step(j, carry):
        x_buf, tokens, h_prev = carry
        tok_j = jax.lax.dynamic_slice_in_dim(tokens, j - 1, 1, 1)[:, 0]
        row = fuse_rows(params, params["embed"][tok_j], h_prev)   # [B,dd]
        x_buf = jax.lax.dynamic_update_slice(
            x_buf, row[:, None, :], (0, C - 1 + j, 0))
        h_all = fwd(x_buf, C + j)                                  # pass j+1
        h_row = jax.lax.dynamic_slice_in_dim(h_all, C - 1 + j, 1, 1)[:, 0]
        t_next = jnp.argmax(h_row @ params["lm_head"], axis=-1).astype(jnp.int32)
        tokens = jax.lax.dynamic_update_slice(tokens, t_next[:, None], (0, j))
        return x_buf, tokens, h_row

    if k > 1:
        h_prev0 = h[:, C - 1]
        x, tokens, _ = jax.lax.fori_loop(1, k, step, (x, tokens0, h_prev0))
    else:
        tokens = tokens0
    return tokens


# ---------------------------------------------------------------------------
# Training forward over prepared MTP row batches (see train.py)
# ---------------------------------------------------------------------------

def train_rows_forward(params, cfg: DrafterConfig, batch, dropout_key=None,
                       h_override=None):
    """Forward over one prepared segment.

    batch dict (all leading dim [B, R]):
      tok_in   int32  — input token per row (depth-0: token_{p+1}; MTP: MASK)
      depth    int32  — row depth d
      pos      int32  — RoPE position p
      feat     f32 [B,R,3dt] — depth-0: feat_p; MTP: feat of the anchor row
      label    int32  — token_{p+2}
      loss_w   f32    — 1.0 for rows owned by this segment, 0 for key-only
      valid    bool   — padding indicator
      mask     bool [B,R,R] — gathered MTP attention mask (masks.py)

    h_override: optional [B,R,dd] replacing the per-row hidden input (TTT
    second pass for the AR baseline). Returns (loss, aux dict).
    """
    tok_in, depth = batch["tok_in"], batch["depth"]
    feat, label = batch["feat"], batch["label"]
    loss_w, valid, mask = batch["loss_w"], batch["valid"], batch["mask"]
    B, R = tok_in.shape

    if h_override is None:
        h_ntp = feat @ params["proj_feat"]
        h_mtp = mtp_hidden(params, cfg, depth, feat, dropout_key)
        h_in = jnp.where((depth == 0)[..., None], h_ntp, h_mtp)
    else:
        h_in = h_override
    x = fuse_rows(params, params["embed"][tok_in], h_in)

    bias = mask_to_bias(mask & valid[:, None, :])[:, None]      # [B,1,R,R]
    h = drafter_blocks(params, cfg, x, batch["pos"], bias, attn_impl="jnp")
    logits = h @ params["lm_head"]

    w = loss_w * valid.astype(jnp.float32)
    loss = cross_entropy(logits, label, valid=w)
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == label).astype(jnp.float32)
    wsum = jnp.maximum(jnp.sum(w), 1.0)
    ntp_w = w * (depth == 0)
    mtp_w = w * (depth > 0)
    aux = {
        "acc": jnp.sum(hit * w) / wsum,
        "ntp_acc": jnp.sum(hit * ntp_w) / jnp.maximum(jnp.sum(ntp_w), 1.0),
        "mtp_acc": jnp.sum(hit * mtp_w) / jnp.maximum(jnp.sum(mtp_w), 1.0),
        "hidden": h,
    }
    return loss, aux
