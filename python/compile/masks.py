"""Scalable attention-mask machinery for parallel-prediction training (paper §3).

Row coordinates. A training row is a pair (p, d): RoPE/sequence position p and
prediction depth d (the paper's "group" G_d). Row (p, d) is anchored at the
real context position a = p - d, consumes a mask token for d >= 1, and predicts
token_{p+1}. The attention rule (derived from chain drafting — see DESIGN.md):

    row (p, d) may attend row (q, e)  iff
        e == 0 and q <= p - d            (the real NTP context), or
        q - e == p - d and e <= d        (its own mask chain, incl. self)

This rule depends only on (p, d, q, e) — *position-invariant* (paper Fig. 3) —
so under the position-major interleaved layout row_id = p*K + d, the mask for
any sequence length n is exactly the top-left (nK x nK) submatrix of the mask
for the maximum length. `PrecomputedMask` builds the max mask once and serves
per-example masks as O(1) slices (+ an index gather when COD sampling is on).

`pard_mask` is the baseline: PARD-style from-scratch per-example construction,
O((nK)^2) predicate evaluations per example (the 48x data-loading slowdown of
paper Table 2).

COD (Conditional Drop-token, PARD / paper §3): geometric retention — depth d
keeps round(n * r^d) anchors. We sample anchors *nested* (A_d ⊆ A_{d-1}), which
the paper's own Figure 4 example satisfies and which Algorithm 1's Phase 2
requires (each row's chain parent must exist).
"""

import numpy as np


def attend_allowed(p, d, q, e):
    """Scalar attention predicate for row (p,d) attending (q,e).

    Rows with p < d (or q < e) never arise in training (their anchor would
    precede the sequence) — report False so all construction paths agree.
    """
    if d > p or e > q:
        return False
    if e == 0 and q <= p - d:
        return True
    if q - e == p - d and e <= d:
        return True
    return False


def full_mask_dense(n, k):
    """Vectorized construction of the full interleaved mask for n positions,
    k depths. Returns bool [n*k, n*k] with row_id = p*k + d."""
    ids = np.arange(n * k)
    p = ids // k
    d = ids % k
    P, Q = p[:, None], p[None, :]
    D, E = d[:, None], d[None, :]
    valid = (D <= P) & (E <= Q)
    ctx = (E == 0) & (Q <= P - D)
    chain = (Q - E == P - D) & (E <= D)
    return valid & (ctx | chain)


class PrecomputedMask:
    """Paper §3.1: amortized mask construction.

    Built once for (n_max, k); per-example masks for any n <= n_max are
    constant-time views (`slice_view`), and COD-sampled row subsets are cheap
    gathers over that view (`gather`).
    """

    def __init__(self, n_max, k):
        self.n_max = n_max
        self.k = k
        self.mask = full_mask_dense(n_max, k)

    def slice_view(self, n):
        assert n <= self.n_max, f"n={n} exceeds precomputed n_max={self.n_max}"
        m = n * self.k
        return self.mask[:m, :m]  # numpy basic slicing: a view, no copy

    def gather(self, rows):
        """rows: int array of interleaved row ids (p*k + d), sorted.
        Returns bool [len(rows), len(rows)] — the attention mask over the
        sampled row subset."""
        rows = np.asarray(rows)
        return self.mask[np.ix_(rows, rows)]


def pard_mask(rows, k):
    """PARD baseline: per-example from-scratch construction with scalar
    predicate evaluation over all row pairs — O(len(rows)^2) Python/loop work
    per example (the Table 2 bottleneck). `rows` are interleaved ids."""
    m = len(rows)
    out = np.zeros((m, m), dtype=bool)
    for i in range(m):
        p, d = rows[i] // k, rows[i] % k
        for j in range(m):
            q, e = rows[j] // k, rows[j] % k
            out[i, j] = attend_allowed(p, d, q, e)
    return out


# ---------------------------------------------------------------------------
# COD sampling (nested anchors)
# ---------------------------------------------------------------------------

def cod_sample(n, k, ratio, rng):
    """Sample nested anchor sets per depth.

    Returns `anchors`: list of k sorted int arrays; anchors[d] are the real
    context positions a whose depth-d row (p = a + d) is kept. anchors[0] is
    all of [0, n-1]; |anchors[d]| = round(n * ratio^d); anchors[d] ⊆
    anchors[d-1]. Rows (p, d) with p > n-2 predict beyond the sequence and are
    dropped by the caller via `valid_rows`.
    """
    anchors = [np.arange(n)]
    for d in range(1, k):
        want = int(round(n * (ratio ** d)))
        prev = anchors[-1]
        want = min(want, len(prev))
        keep = rng.choice(len(prev), size=want, replace=False)
        anchors.append(np.sort(prev[keep]))
    return anchors


def rows_from_anchors(anchors, n, k):
    """Interleaved row ids for the sampled anchor sets, sorted ascending.

    Drops rows whose label token_{p+1} would fall outside the sequence
    (p >= n-1) and rows whose position p = a + d exceeds n-1.
    """
    ids = []
    for d, anc in enumerate(anchors):
        p = anc + d
        p = p[p <= n - 2]
        ids.append(p * k + d)
    ids = np.concatenate(ids)
    return np.sort(ids)


def expected_total_rows(n, k, ratio):
    """Paper §3.2: total positions ≈ n * (1 - r^K) / (1 - r)."""
    return n * (1.0 - ratio ** k) / (1.0 - ratio)


# ---------------------------------------------------------------------------
# Serve-time draft-tree topologies (mirror of rust/src/masking/tree.rs)
# ---------------------------------------------------------------------------
#
# A static draft tree is a width profile: widths[d] nodes at depth d+1,
# level-major node ids 1..N below an implicit root (id 0, the last committed
# token), children attached round-robin so rank-0 parents fill first. The
# chain is the degenerate profile [1]*K. The cross-node ancestor mask is the
# chunk-internal attention rule of one-pass tree verification; the Rust
# engine builds it once per topology and passes it to the tree-verify
# executable as a runtime input.

def tree_topology_id(widths):
    """Canonical topology id shared with the Rust engine
    (masking/tree.rs TreeTopology::id): "chain<K>" for all-ones profiles,
    "w<w1>x<w2>x.." otherwise. Used in executable names and the manifest
    `topology` field — the two sides must agree byte-for-byte."""
    if all(w == 1 for w in widths):
        return f"chain{len(widths)}"
    return "w" + "x".join(str(w) for w in widths)


def tree_parents(widths):
    """Parent id per node (ids 1..N level-major; root = 0).

    Returns an int list of length N where entry i-1 is node i's parent."""
    parents = []
    prev_start, prev_w = 0, 1
    for d, w in enumerate(widths):
        assert w >= 1, f"zero-width tree level in {widths}"
        level_start = len(parents) + 1
        for j in range(w):
            parents.append(0 if d == 0 else prev_start + (j % prev_w))
        prev_start, prev_w = level_start, w
    return parents


def tree_depths(widths):
    """Depth offset per CHUNK slot: [0, depth_1 .. depth_N] (root included).

    Slot j's RoPE position at serve time is cache_len + tree_depths[j]."""
    out = [0]
    for d, w in enumerate(widths):
        out.extend([d + 1] * w)
    return out


# ---------------------------------------------------------------------------
# Paged KV reference (mirror of rust/src/runtime/kv_blocks.rs)
# ---------------------------------------------------------------------------

def paged_logical_view(pool, block_table):
    """Pure-numpy reference for block-table indirection: pool
    [L,2,NB,BS,H,Dh] + block_table [B,M] int -> the dense logical view
    [L,2,B,M*BS,H,Dh] (logical position q of row b lives in pool block
    block_table[b, q // BS] at offset q % BS).

    This is the contract `model.paged_gather` lowers and the Rust engine's
    host-side block surgery (`runtime::kv_blocks`) must preserve; the paged
    parity tests diff both against it."""
    pool = np.asarray(pool)
    table = np.asarray(block_table)
    g = pool[:, :, table]                       # [L,2,B,M,BS,H,Dh]
    L, two, B, M, BS, H, Dh = g.shape
    return g.reshape(L, two, B, M * BS, H, Dh)


def tree_select_nodes(widths, joint_logp, budget):
    """Reference dynamic-tree node selection (mirror of
    rust/src/masking/dynamic.rs `select_nodes`): greedy frontier expansion
    by joint log-probability, ties broken by ascending node id, NaN treated
    as -inf. Returns the selected envelope node ids (1..N) sorted ascending
    — always an ancestor-closed set of size min(budget, N), and (because a
    child's joint log-probability never exceeds its parent's) the global
    top-`budget` by score."""
    parents = tree_parents(widths)
    n = len(parents)
    joint = np.where(np.isnan(joint_logp), -np.inf, np.asarray(joint_logp, float))
    assert joint.shape == (n,), f"need one joint logp per node, got {joint.shape}"
    selected = {0}
    out = []
    for _ in range(min(budget, n)):
        best = None
        for i in range(1, n + 1):
            if i in selected or parents[i - 1] not in selected:
                continue
            if best is None or joint[i - 1] > joint[best - 1]:
                best = i
        selected.add(best)
        out.append(best)
    return sorted(out)


def tree_subset_mask(widths, selected):
    """Reference per-step subset mask in the COMPACTED chunk layout (mirror
    of rust/src/masking/dynamic.rs `subset_mask_i32`): the envelope ancestor
    mask gathered over [root] + selected occupies the top-left, everything
    else is 0 — inactive tail slots attend nothing in the chunk and are
    attended by nobody. `selected` must be sorted ascending and
    ancestor-closed (the `tree_select_nodes` contract). Shape stays the
    envelope's [N+1, N+1] (the executable's lowered mask input)."""
    full = tree_ancestor_mask(widths)
    slots = [0] + list(selected)
    n = full.shape[0]
    out = np.zeros((n, n), dtype=bool)
    out[:len(slots), :len(slots)] = full[np.ix_(slots, slots)]
    return out


def tree_subset_depths(widths, selected):
    """Per-chunk-slot RoPE depth offsets in the compacted layout (mirror of
    rust `compacted_depths_i32`): [0, depth(selected_1), .., 0-padding]."""
    depths = tree_depths(widths)
    out = [0] * (len(tree_parents(widths)) + 1)
    for j, node in enumerate(selected):
        out[j + 1] = depths[node]
    return out


def tree_ancestor_mask(widths):
    """Cross-node causal mask over the verify chunk: bool [N+1, N+1] where
    entry (i, j) allows chunk slot i to attend chunk slot j iff j is an
    ancestor-or-self of i. For widths == [1]*K this is exactly the lower
    triangle (chain verification)."""
    parents = tree_parents(widths)
    n = len(parents) + 1
    mask = np.zeros((n, n), dtype=bool)
    for i in range(n):
        cur = i
        while True:
            mask[i, cur] = True
            if cur == 0:
                break
            cur = parents[cur - 1]
    return mask
